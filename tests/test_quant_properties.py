"""Hypothesis property tests for the quantization layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant import (bits_per_weight, dequantize, pack_nibbles,
                         quantization_rmse, quantize, unpack_nibbles)

FMTS = ["q8_0", "q6_k", "q4_k", "q2_k"]

# relative RMS error ceilings per format (random normal weights)
ERROR_BOUND = {"q8_0": 0.02, "q6_k": 0.06, "q4_k": 0.15, "q2_k": 0.45}


@st.composite
def weight_matrices(draw):
    k = draw(st.sampled_from([256, 512, 768]))
    n = draw(st.sampled_from([8, 32, 64]))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    w = np.random.default_rng(seed).normal(size=(k, n)) * scale
    return jnp.asarray(w, jnp.float32)


@given(weight_matrices(), st.sampled_from(FMTS))
@settings(max_examples=20, deadline=None)
def test_roundtrip_error_bounded(w, fmt):
    assert quantization_rmse(w, fmt) < ERROR_BOUND[fmt]


@given(weight_matrices(), st.sampled_from(FMTS))
@settings(max_examples=10, deadline=None)
def test_scale_invariance(w, fmt):
    """Quantization error is (nearly) scale-invariant: rel error of 2w
    matches rel error of w."""
    e1 = quantization_rmse(w, fmt)
    e2 = quantization_rmse(w * 2.0, fmt)
    assert abs(e1 - e2) < 0.05


@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4]))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(seed, bits):
    rng = np.random.default_rng(seed)
    k = 64 * (8 // bits)
    v = jnp.asarray(rng.integers(0, 2**bits, size=(k, 16)), jnp.uint8)
    assert jnp.array_equal(unpack_nibbles(pack_nibbles(v, bits), bits), v)


@given(st.sampled_from(FMTS))
@settings(max_examples=8, deadline=None)
def test_compression_ratio_matches_bpw(fmt):
    w = jnp.asarray(np.random.default_rng(0).normal(size=(1024, 64)),
                    jnp.float32)
    qt = quantize(w, fmt)
    actual_bpw = qt.nbytes() * 8.0 / w.size
    from repro.quant.formats import get_format
    assert abs(actual_bpw - get_format(fmt).bpw_tpu) < 0.7


def test_zero_and_constant_weights():
    """Degenerate inputs must not produce NaN/inf."""
    for fmt in FMTS:
        for w in (jnp.zeros((256, 8)), jnp.full((256, 8), 3.14),
                  jnp.full((256, 8), -1e-30)):
            back = dequantize(quantize(w, fmt))
            assert bool(jnp.all(jnp.isfinite(back))), fmt


def test_bpw_table():
    assert bits_per_weight("q8_0") == 8.5
    assert bits_per_weight("q6_k") == 6.5625
    assert bits_per_weight("q4_k") == 4.5
    assert abs(bits_per_weight("q2_k") - 2.625) < 1e-9
    assert bits_per_weight("f16") == 16.0
