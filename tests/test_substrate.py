"""Substrate tests: data pipeline, checkpointing, fault tolerance,
optimizer, gradient compression, SSD equivalence, MoE invariants."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore,
                              restore_latest, save)
from repro.data import DataConfig, DataLoader, synth_batch
from repro.models.common import MoEConfig
from repro.models.moe import init_moe, moe_forward
from repro.models.ssm import ssd_chunked, ssd_naive
from repro.optim import AdamWConfig, adamw_update, init_adamw
from repro.optim.compression import compress_roundtrip_error
from repro.train.fault_tolerance import (StragglerMonitor,
                                         elastic_remesh_plan, run_resumable)


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------

def test_data_determinism():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
    b1 = synth_batch(cfg, step=7)
    b2 = synth_batch(cfg, step=7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = synth_batch(cfg, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are tokens shifted by one
    full1 = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    assert np.array_equal(full1[:, 1:], b1["labels"])


def test_data_host_sharding():
    c0 = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=0)
    c1 = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=1)
    assert c0.host_batch == 4
    assert not np.array_equal(synth_batch(c0, 0)["tokens"],
                              synth_batch(c1, 0)["tokens"])


def test_dataloader_prefetch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    dl = DataLoader(cfg)
    batches = [next(dl) for _ in range(3)]
    dl.close()
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    assert np.array_equal(batches[0]["tokens"], synth_batch(cfg, 0)["tokens"])


# ----------------------------------------------------------------------
# checkpointing + fault tolerance
# ----------------------------------------------------------------------

def _tree(v=0.0):
    return {"a": jnp.full((4, 3), v), "b": {"c": jnp.arange(5.0) + v}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    save(d, 3, _tree(1.5))
    save(d, 7, _tree(2.5))
    assert latest_step(d) == 7
    got = restore(d, 7, _tree())
    assert float(got["a"][0, 0]) == 2.5
    step, got = restore_latest(d, _tree())
    assert step == 7


def test_checkpoint_atomicity(tmp_path):
    """Uncommitted (no _COMPLETE marker) checkpoints are skipped."""
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    os.makedirs(os.path.join(d, "step_00000009"))  # torn write
    assert latest_step(d) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tree(float(s)))
    ck.close()
    assert latest_step(str(tmp_path)) == 3
    assert restore(str(tmp_path), 3, _tree())["b"]["c"][0] == 3.0


def test_resumable_loop_survives_failures(tmp_path):
    """Injected preemptions; the loop restarts from checkpoints and
    reaches the target step with bit-stable data (counter PRNG)."""

    def train_step(state, batch):
        return state + batch, {"loss": jnp.asarray(float(state))}

    def make_batch(step):
        return jnp.asarray(1.0)

    fails = {5: True, 13: True}

    def injector(step):
        return fails.pop(step, False)

    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    report = run_resumable(
        train_step, lambda: jnp.asarray(0.0), make_batch, ck,
        total_steps=20, ckpt_every=4, failure_injector=injector)
    assert report.final_step == 20
    assert report.restarts == 2


def test_straggler_monitor():
    mon = StragglerMonitor(n_hosts=4, warmup=2)
    for _ in range(5):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
    assert mon.stragglers() == [2]


def test_elastic_remesh_plan():
    assert elastic_remesh_plan(256, 16) == (16, 16)
    assert elastic_remesh_plan(240, 16) == (15, 16)   # one host lost
    assert elastic_remesh_plan(8, 16) is None         # below one TP group


# ----------------------------------------------------------------------
# optimizer + compression
# ----------------------------------------------------------------------

def test_adamw_shrinks_loss_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_adamw(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw of w^2
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_grad_compression_error():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(10000,)) * 1e-3)
    assert compress_roundtrip_error(g) < 0.01   # int8 block quant ~0.4%


# ----------------------------------------------------------------------
# SSD + MoE invariants
# ----------------------------------------------------------------------

def test_ssd_chunked_equals_naive():
    B, S, H, P, N = 2, 96, 3, 8, 4
    ks = [jax.random.PRNGKey(i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.2
    a_log = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N))
    c = jax.random.normal(ks[4], (B, S, N))
    yn = ssd_naive(x, dt, a_log, b, c)
    yc = ssd_chunked(x, dt, a_log, b, c, chunk=32)
    assert float(jnp.max(jnp.abs(yn - yc))) < 1e-4


def test_moe_gates_and_capacity():
    moe = MoEConfig(n_experts=8, top_k=2, d_expert_ff=32,
                    capacity_factor=10.0)  # no drops at this capacity
    p = init_moe(jax.random.PRNGKey(0), 16, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    out, aux = moe_forward(p, x, moe)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux))
    # capacity math: 8-aligned, >= tokens*k/E
    from repro.models.moe import _capacity
    assert _capacity(64, moe) % 8 == 0
    tight = MoEConfig(n_experts=8, top_k=2, d_expert_ff=32,
                      capacity_factor=1.0)
    assert _capacity(64, tight) >= 64 * 2 // 8


def test_moe_dropped_tokens_pass_through():
    """With capacity 0-ish, output ~ 0 for dropped tokens (residual
    passes through at the block level), never NaN."""
    moe = MoEConfig(n_experts=4, top_k=1, d_expert_ff=16,
                    capacity_factor=0.01)
    p = init_moe(jax.random.PRNGKey(0), 8, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    out, _ = moe_forward(p, x, moe)
    assert bool(jnp.all(jnp.isfinite(out)))
