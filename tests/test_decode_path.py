"""Host-sync-free decode path: kernel, sampling, and dispatch parity.

Three layers of invariants:

* kernel -- the length-aware (scalar-prefetch, early-exit) decode
  attention matches the masked reference at ragged lane lengths,
  including dead (length-0) lanes;
* engine -- the fused-sampling multi-token dispatch is token-exact vs
  the per-token path for greedy decode, and dispatch-size invariant for
  seeded temperature sampling (keys fold from the global step index);
* prefill -- power-of-two bucketing bounds XLA recompiles without
  changing the generated stream.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import (
    decode_attention_lengthaware_pallas, decode_attention_pallas,
    decode_attention_q8_lengthaware_pallas, decode_attention_q8_ref,
    decode_attention_ref, kv_blocks_fetched, quantize_kv_q8)
from repro.models import build_model
from repro.serving import Request, ServeEngine


# ----------------------------------------------------------------------
# kernel: length-aware vs masked reference
# ----------------------------------------------------------------------

@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (4, 1)])
def test_lengthaware_matches_ref_ragged(h, hkv):
    b, s, d, bk = 5, 256, 32, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    # ragged lengths: dead lane, sub-block, block-aligned, partial, full
    lens = jnp.array([0, 7, 64, 130, 256], jnp.int32)
    out = decode_attention_lengthaware_pallas(q, k, v, lens, bk=bk,
                                              interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5
    # and it agrees with the masked kernel (the pinned parity reference)
    masked = decode_attention_pallas(q, k, v, lens, bk=bk, interpret=True)
    assert jnp.max(jnp.abs(out - masked)) < 2e-5


def test_lengthaware_dead_lane_zero_output():
    b, h, s, d = 2, 4, 128, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d))
    lens = jnp.array([0, s], jnp.int32)
    out = decode_attention_lengthaware_pallas(q, k, v, lens, bk=32,
                                              interpret=True)
    assert jnp.all(out[0] == 0.0)          # dead lane: no live keys
    assert jnp.any(out[1] != 0.0)


def test_lengthaware_q8_matches_ref():
    b, h, hkv, s, d = 3, 4, 2, 256, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    lens = jnp.array([0, 100, 256], jnp.int32)
    kq, ks = quantize_kv_q8(k)
    vq, vs = quantize_kv_q8(v)
    out = decode_attention_q8_lengthaware_pallas(q, kq, ks, vq, vs, lens,
                                                 bk=64, interpret=True)
    ref = decode_attention_q8_ref(q, kq, ks, vq, vs, lens)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_kv_blocks_fetched_scales_with_length():
    # the modeled fetch count is the contract BENCH_decode costs with
    blocks = kv_blocks_fetched(np.array([0, 1, 64, 65, 512]), 512, 64)
    assert list(blocks) == [1, 1, 1, 2, 8]
    # masked kernel would fetch 8 blocks for every lane
    assert blocks.sum() < 5 * 8


# ----------------------------------------------------------------------
# engine: fused sampling + multi-token dispatch parity
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, prompts, max_new, **kw):
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng = ServeEngine(cfg, params, **kw)
    eng.run(reqs)
    return [tuple(r.generated) for r in reqs], eng


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
            for n in lens]


def test_greedy_token_exact_vs_pertoken_legacy(small_model):
    """The batched/fused engine reproduces the pre-refactor per-token
    path exactly: jitted decode step, host-side argmax, one token per
    dispatch (the shared oracle in benchmarks.llm_decode)."""
    from benchmarks.llm_decode import _legacy_greedy

    cfg, params = small_model
    prompts = _prompts(cfg, [5, 6, 7, 8])
    got, _ = _serve(cfg, params, prompts, 6, n_lanes=2, max_len=32,
                    dispatch_n=8)
    assert [list(g) for g in got] == [
        _legacy_greedy(cfg, params, p, 6, 32) for p in prompts]


def test_greedy_dispatch_size_invariant(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, [5, 9, 6, 12, 7], seed=1)
    outs = [
        _serve(cfg, params, prompts, 7, n_lanes=2, max_len=32,
               dispatch_n=n)[0]
        for n in (1, 3, 8)]
    assert outs[0] == outs[1] == outs[2]


def test_temperature_dispatch_size_invariant(small_model):
    """Sampling keys fold from (admission index, token index), so the
    stochastic path is identical across dispatch granularities -- even
    with queued requests and ragged budgets, where admission timing
    shifts with the dispatch boundary."""
    cfg, params = small_model
    prompts = _prompts(cfg, [5, 8], seed=2)
    a, _ = _serve(cfg, params, prompts, 6, n_lanes=2, max_len=32,
                  dispatch_n=1, temperature=0.9, rng_seed=7)
    b, _ = _serve(cfg, params, prompts, 6, n_lanes=2, max_len=32,
                  dispatch_n=4, temperature=0.9, rng_seed=7)
    assert a == b
    assert all(0 <= t < cfg.padded_vocab for seq in a for t in seq)
    c, _ = _serve(cfg, params, prompts, 6, n_lanes=2, max_len=32,
                  dispatch_n=4, temperature=0.9, rng_seed=8)
    assert c != a          # a different seed actually changes the draw
    # queueing case: 4 requests over 2 lanes, ragged budgets -- at
    # dispatch_n=8 the lane frees (and request 3 is admitted) at a
    # different global step than at dispatch_n=1
    qp = _prompts(cfg, [5, 6, 7, 8], seed=6)

    def serve_ragged(n):
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=2 + 3 * i)
                for i, p in enumerate(qp)]
        ServeEngine(cfg, params, n_lanes=2, max_len=32, dispatch_n=n,
                    temperature=0.9, rng_seed=7).run(reqs)
        return [tuple(r.generated) for r in reqs]

    assert serve_ragged(1) == serve_ragged(8)


def test_dispatch_counters(small_model):
    """>= 5x fewer host dispatches per generated token than per-token."""
    cfg, params = small_model
    prompts = _prompts(cfg, [6] * 4, seed=3)
    _, base = _serve(cfg, params, prompts, 8, n_lanes=4, max_len=32,
                     dispatch_n=1)
    _, new = _serve(cfg, params, prompts, 8, n_lanes=4, max_len=32,
                    dispatch_n=8)
    base_dpt = base.stats["decode_dispatches"] / base.stats[
        "generated_tokens"]
    new_dpt = new.stats["decode_dispatches"] / new.stats["generated_tokens"]
    assert base_dpt / new_dpt >= 5.0
    assert new.stats["generated_tokens"] == 4 * 8


def test_prefill_bucketing_recompile_count(small_model):
    """Five distinct prompt lengths, at most two prefill compiles (the
    8- and 16-token buckets) -- and bucketing does not change tokens."""
    cfg, params = small_model
    prompts = _prompts(cfg, [5, 6, 7, 9, 12], seed=4)
    bucketed, eng = _serve(cfg, params, prompts, 4, n_lanes=2, max_len=32,
                           dispatch_n=4)
    assert eng.stats["prefill_compiles"] <= 2
    exact, eng2 = _serve(cfg, params, prompts, 4, n_lanes=2, max_len=32,
                         dispatch_n=4, prefill_bucketing=False)
    assert eng2.stats["prefill_compiles"] == 5   # one per distinct length
    assert bucketed == exact


def test_run_retires_everything_without_scan(small_model):
    """Continuous admission over more requests than lanes: every request
    retired via dispatch done-flags, budgets exactly honored."""
    cfg, params = small_model
    prompts = _prompts(cfg, [4, 5, 6, 7, 8, 9], seed=5)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3 + (i % 3))
            for i, p in enumerate(prompts)]
    eng = ServeEngine(cfg, params, n_lanes=2, max_len=32, dispatch_n=4)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert [len(r.generated) for r in reqs] == [3 + (i % 3)
                                               for i in range(6)]
    assert all(r is None for r in eng.lane_req)
    # retired lanes are length-zero (the length-aware kernel pins one
    # block for them instead of streaming the stale context)
    assert all(int(x) == 0 for x in eng.cache["len"])


def test_overlong_prompt_truncated_coherently(small_model):
    """A prompt longer than max_len is tail-truncated at admission: the
    engine serves it like the equivalent pre-truncated request instead
    of recording a cache length the lane cannot back."""
    cfg, params = small_model
    long_prompt = _prompts(cfg, [24], seed=9)[0]
    max_len = 16
    r_long = Request(uid=0, prompt=long_prompt.copy(), max_new_tokens=4)
    ServeEngine(cfg, params, n_lanes=1, max_len=max_len,
                dispatch_n=4).run([r_long])
    r_tail = Request(uid=0, prompt=long_prompt[-(max_len - 1):].copy(),
                     max_new_tokens=4)
    ServeEngine(cfg, params, n_lanes=1, max_len=max_len,
                dispatch_n=4).run([r_tail])
    assert r_long.done and r_long.generated == r_tail.generated


@pytest.mark.parametrize("arch", ["mamba2-780m", "hymba-1.5b"])
def test_ssm_lane_reuse_isolation(arch):
    """Re-admitting a lane of a recurrent-family engine must not leak
    the previous request's SSM state: request B through a reused lane
    equals B served solo in a fresh engine."""
    cfg = get_config(arch, smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    pa, pb = _prompts(cfg, [6, 7], seed=8)
    solo = Request(uid=1, prompt=pb.copy(), max_new_tokens=4)
    ServeEngine(cfg, params, n_lanes=1, max_len=32, dispatch_n=4).run([solo])
    seq = [Request(uid=0, prompt=pa.copy(), max_new_tokens=4),
           Request(uid=1, prompt=pb.copy(), max_new_tokens=4)]
    ServeEngine(cfg, params, n_lanes=1, max_len=32, dispatch_n=4).run(seq)
    assert tuple(seq[1].generated) == tuple(solo.generated)
