"""SSD Pallas kernel: shape sweep vs the jnp oracles."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ssd_scan import (ssd_chunk_pallas, ssd_chunked,
                                    ssd_intra_ref, ssd_naive, ssd_pallas)


def _inputs(B, S, H, P, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.2
    a_log = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N))
    c = jax.random.normal(ks[4], (B, S, N))
    return x, dt, a_log, b, c


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 8, 4, 16),
    (2, 128, 3, 16, 8, 32),
    (1, 256, 2, 32, 16, 64),
])
def test_intra_chunk_kernel(B, S, H, P, N, chunk):
    x, dt, a_log, b, c = _inputs(B, S, H, P, N)
    yi, st, dec = ssd_chunk_pallas(x, dt, a_log, b, c, chunk=chunk,
                                   interpret=True)
    ri, rst, rdec = ssd_intra_ref(x, dt, a_log, b, c, chunk=chunk)
    assert float(jnp.max(jnp.abs(yi - ri))) < 1e-5
    assert float(jnp.max(jnp.abs(st - rst))) < 1e-5
    assert float(jnp.max(jnp.abs(dec - rdec))) < 1e-6


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_full_ssd_matches_naive(chunk):
    x, dt, a_log, b, c = _inputs(2, 64, 2, 8, 4, seed=1)
    out = ssd_pallas(x, dt, a_log, b, c, chunk=chunk, interpret=True)
    ref = ssd_naive(x, dt, a_log, b, c)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_kernel_consistent_with_model_layer():
    """The kernel path and the model's jnp path agree (the swap-in
    criterion for TPU deployment of the mamba2/hymba archs)."""
    x, dt, a_log, b, c = _inputs(1, 96, 3, 8, 4, seed=2)
    a = ssd_pallas(x, dt, a_log, b, c, chunk=32, interpret=True)
    bb = ssd_chunked(x, dt, a_log, b, c, chunk=32)
    assert float(jnp.max(jnp.abs(a - bb))) < 1e-5
