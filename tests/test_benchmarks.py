"""Benchmark harness gates: every module yields rows; every paper-claim
row PASSes; the CSV contract (name,us_per_call,derived) holds."""

import pytest


def _rows(mod):
    rows = mod.rows()
    assert rows, mod.__name__
    for r in rows:
        assert isinstance(r.name, str) and r.name
        assert isinstance(r.us_per_call, float)
    return rows


def _claims_pass(rows):
    claims = [r for r in rows if r.name.startswith("claim_")]
    assert claims, "no claim rows"
    for r in claims:
        assert "FAIL" not in str(r.derived), f"{r.name}: {r.derived}"


def test_compute_sweep_claims():
    from benchmarks import compute_sweep
    _claims_pass(_rows(compute_sweep))


def test_membw_claims():
    from benchmarks import membw
    _claims_pass(_rows(membw))


def test_llm_prefill_claims():
    from benchmarks import llm_prefill
    _claims_pass(_rows(llm_prefill))


def test_llm_decode_claims():
    from benchmarks import llm_decode
    _claims_pass(_rows(llm_decode))


def test_efficiency_claims():
    from benchmarks import efficiency
    _claims_pass(_rows(efficiency))


def test_cost_model_claims():
    from benchmarks import cost_model
    _claims_pass(_rows(cost_model))


def test_interconnect_rows():
    from benchmarks import interconnect
    _rows(interconnect)


def test_hetero_serving_gain():
    from benchmarks import hetero_serving
    rows = _rows(hetero_serving)
    gain_row = [r for r in rows if r.name == "fleet_disaggregation_gain"][0]
    gain = float(str(gain_row.derived).split("x")[0])
    assert gain > 1.0, "disaggregation must beat homogeneous fleets"


def test_fleet_sim_goodput_gain():
    from benchmarks import fleet_sim
    rows = _rows(fleet_sim)
    gain_row = [r for r in rows if r.name == "fleet_sim_goodput_gain"][0]
    gain = float(str(gain_row.derived).split("x")[0])
    assert gain > 1.0, "simulated disaggregation must win on goodput"
    agree = [r for r in rows if r.name == "fleet_sim_vs_planner"][0]
    ratio = float(str(agree.derived).split("ratio=")[1])
    assert 0.9 <= ratio <= 1.1, "simulator must agree with plan_fleet"
