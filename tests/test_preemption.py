"""Preemption & KV-page migration: evict-and-replay end to end.

Four layers of invariants:

* engine -- a lane evicted mid-decode and restored (same engine or a
  fresh one with the same config/seed) produces the EXACT token stream
  of an unpreempted run, for greedy and temperature sampling, dense and
  int8 KV caches, and the hybrid (attention + SSM) family;
* allocator -- PagePool conservation / no-double-free across
  evict->migrate->restore churn, and the scratch page is never
  allocated, captured, or remapped;
* admission -- worst-case page need is clamped to what the cache can
  back (over-budget requests stay admissible), and ``run()`` fails
  loudly instead of livelocking when the head request can never be
  admitted;
* fleet -- the simulator migrates page-granular KV over the host link
  deterministically: page-exhaustion preemption relieves a saturated
  board, and the execution replay's token accounting is preemption
  invariant.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine

pytestmark = pytest.mark.preempt


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
            for n in lens]


def _reqs(prompts, max_new):
    return [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


ENGINE_KW = dict(n_lanes=2, max_len=32, dispatch_n=4, paged=True,
                 page_size=8, rng_seed=7)


def _drain(*engines):
    """Decode every engine until all its lanes retire."""
    for eng in engines:
        while eng.live_lanes():
            eng.decode_n()


# ----------------------------------------------------------------------
# engine: evict -> restore token exactness
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.9])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
@pytest.mark.parametrize("cross_engine", [False, True])
def test_evict_restore_token_exact(small_model, temperature, kv_quant,
                                   cross_engine):
    """Mid-decode eviction + restore reproduces the unpreempted stream
    bit-identically -- the checkpoint carries the sampling identity
    (lane_seed, tok_idx) and the pre-sampled next token, so the RNG
    lineage continues instead of restarting."""
    cfg, params = small_model
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    prompts = _prompts(cfg, [5, 9], seed=1)
    kw = dict(ENGINE_KW, temperature=temperature)

    base = _reqs(prompts, 12)
    eng = ServeEngine(cfg, params, **kw)
    eng.run(base)

    reqs = _reqs(prompts, 12)
    src = ServeEngine(cfg, params, **kw)
    for r in reqs:
        assert src.admit(r)
    src.decode_n()                       # 4 tokens into each stream
    ckpt = src.evict(0)
    src.decode_n()                       # lane 1 advances alone
    dst = ServeEngine(cfg, params, **kw) if cross_engine else src
    assert dst.restore(ckpt)
    _drain(src, dst)

    assert [r.generated for r in reqs] == [r.generated for r in base]
    src.pool.check()
    dst.pool.check()
    assert src.pool.n_in_use == 0 and dst.pool.n_in_use == 0
    assert src.stats["preemptions"] == 1
    assert dst.stats["restores"] == 1
    assert dst.stats["pages_migrated"] == ckpt.n_pages > 0


def test_evict_restore_hybrid_ssm_state(small_model):
    """Hybrid family: the checkpoint must carry the recurrent SSM state
    alongside the KV pages, or the resumed stream diverges."""
    cfg = get_config("hymba-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg, [6, 7], seed=5)

    base = _reqs(prompts, 8)
    ServeEngine(cfg, params, **ENGINE_KW).run(base)

    reqs = _reqs(prompts, 8)
    eng = ServeEngine(cfg, params, **ENGINE_KW)
    for r in reqs:
        assert eng.admit(r)
    eng.decode_n()
    ckpt = eng.evict(1)
    assert ckpt.ssm_state                  # recurrent state captured
    eng.decode_n()
    assert eng.restore(ckpt)
    _drain(eng)
    assert [r.generated for r in reqs] == [r.generated for r in base]
    eng.pool.check()


def test_checkpoint_is_host_side_and_sized(small_model):
    """The checkpoint payload is numpy (shippable) and its page count is
    exactly ceil((ctx+1)/page_size) -- the fleet's transfer unit."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, **ENGINE_KW)
    req = Request(uid=0, prompt=_prompts(cfg, [9], seed=2)[0],
                  max_new_tokens=8)
    assert eng.admit(req)
    eng.decode_n()
    ctx = eng.lane_context(0)
    ckpt = eng.evict(0)
    assert ckpt.ctx_len == ctx == 9 + 4
    assert all(isinstance(v, np.ndarray) for v in ckpt.kv_pages.values())
    assert ckpt.n_pages == -(-(ctx + 1) // eng.page_size)
    assert ckpt.nbytes() > 0
    assert ckpt.remaining == 4


# ----------------------------------------------------------------------
# allocator: churn + scratch-page invariants
# ----------------------------------------------------------------------

def test_pagepool_conservation_across_evict_restore_churn(small_model):
    """Evict->hold->restore cycles injected into admit/retire churn:
    conservation holds at every dispatch boundary, nothing double-frees,
    the pool drains to empty, and the scratch page never enters the
    allocator, a checkpoint, or a mapped table row."""
    cfg, params = small_model
    pool = 6
    eng = ServeEngine(cfg, params, n_lanes=3, max_len=32, dispatch_n=4,
                      paged=True, page_size=8, n_pages=pool)
    scratch = eng._scratch_page
    rng = np.random.default_rng(4)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 3 + (i % 7),
                                        dtype=np.int32),
                    max_new_tokens=2 + (i % 5))
            for i in range(14)]
    pending = list(reqs)
    held = []
    blocks = 0
    while pending or held or eng.live_lanes():
        while held and eng.restore(held[0]):
            held.pop(0)
        if not held:
            while pending and eng.free_lanes():
                if not eng.admit(pending[0]):
                    break
                pending.pop(0)
        if eng.live_lanes():
            eng.decode_n()
        blocks += 1
        if blocks % 2 == 0 and eng.live_lanes():
            lane = max(eng.live_lanes(), key=eng.lane_context)
            held.append(eng.evict(lane))
        eng.pool.check()                   # conservation every block
        assert eng.pool.hwm <= pool
        for lane_pages in eng._lane_pages:
            assert scratch not in lane_pages
    assert all(r.done for r in reqs)
    assert [len(r.generated) for r in reqs] == [2 + (i % 5)
                                                for i in range(14)]
    assert eng.pool.n_in_use == 0 and eng.pool.n_free == pool
    assert eng.pool.alloc_count == eng.pool.free_count > 0
    assert eng.stats["preemptions"] == eng.stats["restores"] > 0


def test_scratch_page_never_migrates(small_model):
    """Eviction gathers only allocator-issued pages; restore maps only
    allocator-issued pages; freed lanes point at the scratch row."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, **ENGINE_KW)
    scratch = eng._scratch_page
    reqs = _reqs(_prompts(cfg, [9, 7], seed=7), 8)
    for r in reqs:
        assert eng.admit(r)
    eng.decode_n()
    ckpt = eng.evict(0)
    # the evicted lane's table row is parked on the scratch page
    assert bool(np.all(np.asarray(eng.cache["block_tables"][0]) == scratch))
    assert eng.restore(ckpt)
    mapped = np.asarray(eng.cache["block_tables"][0][:ckpt.n_pages])
    assert scratch not in mapped
    assert set(mapped.tolist()) == set(eng._lane_pages[0])
    _drain(eng)
    eng.pool.check()


# ----------------------------------------------------------------------
# admission clamp + run() no-progress guard
# ----------------------------------------------------------------------

def test_admission_pages_clamped_to_cache_capacity(small_model):
    """A budget far beyond max_len must not demand more pages than the
    cache can ever back: generation stops at the len cap, so the
    worst-case need is _pages_needed(max_len) and the request stays
    admissible on a pool of exactly one full context."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_lanes=2, max_len=32, dispatch_n=4,
                      paged=True, page_size=8, n_pages=4)
    req = Request(uid=0, prompt=_prompts(cfg, [5], seed=3)[0],
                  max_new_tokens=10_000)
    assert eng.admission_pages(req) == eng._pages_needed(eng.max_len) == 4
    assert eng.can_admit(req)
    eng.run([req])
    assert req.done
    assert len(req.generated) == eng.max_len - 1 - 5   # stopped at cap
    eng.pool.check()


def test_run_raises_instead_of_livelock(small_model):
    """An engine that can NEVER admit the head request (nothing in
    flight to retire) must raise, not spin on no-op dispatches."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_lanes=0, max_len=32, dispatch_n=4)
    req = Request(uid=0, prompt=_prompts(cfg, [5], seed=3)[0],
                  max_new_tokens=4)
    with pytest.raises(RuntimeError, match="never be admitted"):
        eng.run([req])


def test_decode_n_skips_dispatch_with_no_live_lanes(small_model):
    """No live lanes -> no device dispatch (and no stats movement)."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, **ENGINE_KW)
    before = dict(eng.stats)
    assert eng.decode_n() == {}
    assert eng.stats == before


# ----------------------------------------------------------------------
# fleet: page-granular migration over the host link
# ----------------------------------------------------------------------

def _saturated_fleet():
    from repro.fleet import NodeSpec
    return [NodeSpec("a100-40g", 1, "prefill"),
            NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                     kv_pool_pages=40, page_size=16),
            NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                     kv_pool_pages=512, page_size=16)]


def _tail_trace():
    from repro.fleet import poisson_trace
    from repro.fleet.workload import LengthDist
    return poisson_trace(3.0, 40.0, seed=2, prompt=LengthDist(256, cv=0.3),
                         gen=LengthDist(128, cv=0.5))


def test_fleet_page_exhaustion_migration_relieves_saturated_node():
    """With migration on, the board whose pool over-commits sheds its
    longest decodes to the peer with page headroom: preemptions happen,
    pages move, every request still completes, and the per-token tail
    improves (paying ~ms of page transfer instead of the ~1000x host-
    link spill on every step)."""
    from repro.fleet import FleetSim, PreemptionPolicy

    trace = _tail_trace()
    base = FleetSim(_saturated_fleet(), trace, fmt="q8_0").run()
    sim = FleetSim(_saturated_fleet(), trace, fmt="q8_0",
                   preemption=PreemptionPolicy())
    mig = sim.run()
    assert base.preemptions == 0 and base.pages_migrated == 0
    assert mig.preemptions > 0
    assert mig.pages_migrated > 0
    assert mig.completed == mig.offered
    assert mig.tpot_p99_s < base.tpot_p99_s
    assert len(mig.preempt_events) == mig.preemptions
    # per-record accounting agrees with the fleet-level counter
    assert sum(r.preemptions for r in sim.records) == mig.preemptions
    # in-flight page reservations all landed and were released
    assert all(n.inbound_pages == 0 and n.inbound_inflight == 0
               for n in sim.nodes + sim.retired)


def test_fleet_migration_deterministic():
    from repro.fleet import FleetSim, PreemptionPolicy

    trace = _tail_trace()
    r1 = FleetSim(_saturated_fleet(), trace, fmt="q8_0",
                  preemption=PreemptionPolicy()).run()
    r2 = FleetSim(_saturated_fleet(), trace, fmt="q8_0",
                  preemption=PreemptionPolicy()).run()
    assert r1.metrics() == r2.metrics()
    assert r1.preempt_events == r2.preempt_events


def test_migration_transfer_time_is_page_granular():
    """The sim charges ceil(ctx/page_size) pages through the bottleneck
    host link -- the same arithmetic the engine checkpoint ships."""
    from repro.core.device_profile import get_profile
    from repro.fleet import SimNode
    from repro.serving import kv_handoff_seconds

    cmp_prof = get_profile("cmp-170hx-nofma")
    node = SimNode("n0", cmp_prof, "decode", "q8_0", page_size=16)
    assert node.migration_pages(1) == 1
    assert node.migration_pages(16) == 1
    assert node.migration_pages(17) == 2
    assert node.migration_pages(260) == 17
    t = node.kv_page_transfer_s(17, peer=get_profile("a100-40g"))
    assert t == pytest.approx(
        kv_handoff_seconds(cmp_prof, 17 * 16, node.spec,
                           peer=get_profile("a100-40g")))
    # 17 pages x 16 tok x ~28.7KB/tok over ~1 GB/s: milliseconds, and
    # strictly worse over the CMP's own link than over the A100's
    assert node.kv_page_transfer_s(17) >= t


def test_straggler_policy_bounded_by_migration_cap():
    """straggler_factor migrates at most max_migrations_per_request
    times per uid -- no ping-pong."""
    from repro.fleet import FleetSim, PreemptionPolicy

    trace = _tail_trace()
    pol = PreemptionPolicy(on_page_exhaustion=True, straggler_factor=1.5,
                           max_migrations_per_request=1)
    rep = FleetSim(_saturated_fleet(), trace, fmt="q8_0",
                   preemption=pol).run()
    assert rep.completed == rep.offered
    per_uid = {}
    for ev in rep.preempt_events:
        uid = int(ev.split("uid=")[1].split()[0])
        per_uid[uid] = per_uid.get(uid, 0) + 1
    assert per_uid and max(per_uid.values()) <= 1


# ----------------------------------------------------------------------
# execution replay: preemption-invariant token accounting
# ----------------------------------------------------------------------

def test_execution_replay_preemption_invariant(small_model):
    """Replaying the trace with evict-and-replay churn must not change a
    single token, and the counters must surface the churn."""
    from repro.fleet.execution import (run_trace_on_engine,
                                       validate_preemption_exactness)
    from repro.fleet.workload import FleetRequest

    cfg, params = small_model
    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=5 + i,
                          gen_len=8) for i in range(5)]
    kw = dict(n_lanes=2, max_len=32, dispatch_n=4, page_size=8)
    plain = run_trace_on_engine(trace, cfg, params, paged=True, **kw)
    churn = run_trace_on_engine(trace, cfg, params, paged=True,
                                preempt_every=1, **kw)
    assert churn.gen_by_uid == plain.gen_by_uid
    assert churn.preemptions == churn.restores > 0
    assert churn.pages_migrated > 0
    assert plain.preemptions == 0

    result = validate_preemption_exactness(trace, cfg, params,
                                           preempt_every=1,
                                           temperature=0.8, **kw)
    assert result["resume_exact"], result["mismatches"]
    assert result["preemptions"] > 0
