"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

Every Pallas kernel is validated in interpret mode (kernel body executes
on CPU) against its ``ref.py`` oracle.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention import (decode_attention_pallas,
                                            decode_attention_q8_pallas,
                                            decode_attention_q8_ref,
                                            decode_attention_ref,
                                            quantize_kv_q8)
from repro.kernels.flash_attention import (attention_ref,
                                           flash_attention_pallas)
from repro.kernels.flash_attention.blockwise import blockwise_attention
from repro.kernels.fma_matmul import fma_matmul_pallas, matmul_ref
from repro.kernels.mixbench import mixbench_pallas, mixbench_ref
from repro.kernels.qmatmul import (qmatmul_i8_ref, qmatmul_pallas,
                                   qmatmul_ref)
from repro.quant import quantize


# ----------------------------------------------------------------------
# fma_matmul
# ----------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["mxu", "mul_add"])
@pytest.mark.parametrize("m,k,n,dtype", [
    (32, 128, 128, jnp.float32),
    (64, 256, 384, jnp.float32),
    (16, 512, 128, jnp.bfloat16),
])
def test_fma_matmul(variant, m, k, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = fma_matmul_pallas(x, w, variant=variant, bm=16, bk=128, bn=128,
                            interpret=True)
    ref = matmul_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9) < tol


def test_fma_variants_agree():
    """The two compute paths are numerically equivalent (paper: same
    result, different instruction mix)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    a = fma_matmul_pallas(x, w, variant="mxu", interpret=True)
    b = fma_matmul_pallas(x, w, variant="mul_add", interpret=True)
    assert jnp.max(jnp.abs(a - b)) < 1e-3


# ----------------------------------------------------------------------
# qmatmul
# ----------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["q8_0", "q6_k", "q4_k", "q2_k"])
@pytest.mark.parametrize("m,k,n", [(16, 256, 128), (32, 512, 256),
                                   (8, 1024, 128)])
def test_qmatmul_dequant(fmt, m, k, n):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    qt = quantize(w, fmt)
    out = qmatmul_pallas(x, qt, variant="dequant_dot", bm=8, bk=256, bn=128,
                         interpret=True)
    ref = qmatmul_ref(x, qt)
    assert jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9) < 1e-5


@pytest.mark.parametrize("k", [256, 512])
def test_qmatmul_dot_i8(k):
    x = jax.random.normal(jax.random.PRNGKey(0), (16, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, 128), jnp.float32)
    qt = quantize(w, "q8_0")
    out = qmatmul_pallas(x, qt, variant="dot_i8", bm=8, bk=256, bn=128,
                         interpret=True)
    ref = qmatmul_i8_ref(x, qt)
    assert jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9) < 1e-5


def test_qmatmul_quant_error_bounded():
    """Kernel output vs the TRUE (unquantized) product stays within the
    format's expected error envelope."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 128), jnp.float32)
    exact = x @ w
    bounds = {"q8_0": 0.02, "q6_k": 0.06, "q4_k": 0.2, "q2_k": 0.8}
    for fmt, bound in bounds.items():
        qt = quantize(w, fmt)
        out = qmatmul_pallas(x, qt, variant="dequant_dot", interpret=True,
                             bm=8, bk=256, bn=128)
        rel = float(jnp.sqrt(jnp.mean((out - exact) ** 2))
                    / jnp.sqrt(jnp.mean(exact ** 2)))
        assert rel < bound, (fmt, rel)


# ----------------------------------------------------------------------
# mixbench
# ----------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["fma", "mul_add"])
@pytest.mark.parametrize("iters", [1, 16, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mixbench(variant, iters, dtype):
    x = jnp.linspace(0, 1, 2048).astype(dtype)
    out = mixbench_pallas(x, iters=iters, variant=variant, block=512,
                          interpret=True)
    ref = mixbench_ref(x, iters)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < tol


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 32)])
@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention(causal, window, h, hkv):
    b, s, d = 2, 128, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=32, bk=32, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_blockwise_matches_naive(causal, window):
    b, h, hkv, s, d = 2, 4, 2, 256, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              block=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("h,hkv,s", [(4, 4, 128), (8, 2, 256), (4, 1, 512)])
def test_decode_attention(h, hkv, s):
    b, d = 3, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    lens = jnp.array([s, s // 2, 7], jnp.int32)
    out = decode_attention_pallas(q, k, v, lens, bk=64, interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_decode_attention_q8_kv():
    b, h, hkv, s, d = 2, 4, 2, 256, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    lens = jnp.array([s, 100], jnp.int32)
    kq, ks = quantize_kv_q8(k)
    vq, vs = quantize_kv_q8(v)
    out = decode_attention_q8_pallas(q, kq, ks, vq, vs, lens, bk=64,
                                     interpret=True)
    ref = decode_attention_q8_ref(q, kq, ks, vq, vs, lens)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5
    # and the quantized path tracks the dense one within int8 KV error
    dense = decode_attention_ref(q, k, v, lens)
    assert jnp.max(jnp.abs(out - dense)) < 0.05
