"""Serving engine + disaggregation planner tests."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (Request, ServeEngine, Workload,
                           dequantize_params, homogeneous_baseline,
                           plan_fleet, quantize_params)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 10,
                                        dtype=np.int32),
                    max_new_tokens=5) for i in range(5)]
    engine = ServeEngine(cfg, params, n_lanes=2, max_len=32)
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 5 for r in reqs)
    assert all(0 <= t < cfg.padded_vocab for r in reqs for t in r.generated)


def test_engine_deterministic_greedy(small_model):
    cfg, params = small_model
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    outs = []
    for _ in range(2):
        r = Request(uid=0, prompt=prompt, max_new_tokens=6)
        ServeEngine(cfg, params, n_lanes=1, max_len=24).run([r])
        outs.append(tuple(r.generated))
    assert outs[0] == outs[1]


def test_continuous_batching_isolation(small_model):
    """A request's output must not depend on its lane neighbors."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab_size, 10, dtype=np.int32)
    solo = Request(uid=0, prompt=p0, max_new_tokens=5)
    ServeEngine(cfg, params, n_lanes=1, max_len=32).run([solo])
    together = [Request(uid=0, prompt=p0, max_new_tokens=5),
                Request(uid=1,
                        prompt=rng.integers(0, cfg.vocab_size, 10,
                                            dtype=np.int32),
                        max_new_tokens=5)]
    ServeEngine(cfg, params, n_lanes=2, max_len=32).run(together)
    assert tuple(solo.generated) == tuple(together[0].generated)


def test_quantize_params_stats(small_model):
    cfg, params = small_model
    qp, stats = quantize_params(params, "q4_k")
    assert stats["quantized"] > 0
    dense = dequantize_params(qp)
    ref_leaves = jax.tree_util.tree_leaves(params)
    got_leaves = jax.tree_util.tree_leaves(dense)
    assert len(ref_leaves) == len(got_leaves)
    assert all(a.shape == b.shape for a, b in zip(ref_leaves, got_leaves))


def test_disaggregation_prefers_split_roles():
    plan = plan_fleet({"a100-40g": 2, "cmp-170hx-nofma": 8}, Workload())
    roles = {a.profile: a.role for a in plan.assignments}
    assert roles["a100-40g"] in ("prefill", "both")
    assert roles["cmp-170hx-nofma"] in ("decode", "both")
    homog = homogeneous_baseline("a100-40g", 2, Workload())
    assert plan.requests_per_s >= homog.requests_per_s
