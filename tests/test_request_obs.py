"""Request-scoped observability: timelines, flight recorder, SLO loop.

Five layers of invariants:

* timelines -- :class:`~repro.obs.RequestTimeline` selects one uid's
  records (scalar ``uid`` and batch ``uids``), orders them causally,
  derives TTFT/tpot/pages/hops, and its ``gaps()`` contract calls a
  clean life complete, tolerates the one unmatched restore a crash
  migration legitimately produces per engine hop, and flags real gaps;
* exporters -- Chrome-trace round-trip (``spans_from_chrome`` inverts
  ``export_chrome_trace`` with exact durations), per-request track
  re-projection, and Prometheus text exposition;
* flight recorder -- bounded ring with honest drop accounting, hook
  chaining on attach, dump/load round-trip, and ``flight_guard``
  dumping on ``AssertionError`` subclasses only;
* SLO -- burn-rate arithmetic, the both-windows alert rule with
  short-window-clears hysteresis, None-objective sample dropping (no
  dilution), and the controller's one-move-per-step pacing;
* integration -- the full stack on a real engine is exactness-neutral,
  every request reconstructs a gap-free timeline, the clock-skew fix
  holds (one monotonic clock everywhere), lint R003 flags a wall clock
  handed to an obs constructor, the schema snapshot matches
  ``docs/observability.md``, and the dump CLI sniffs all three
  artifact shapes.
"""

import json
import os
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis.lint import lint_source
from repro.configs import get_config
from repro.models import build_model
from repro.obs import (BurnRateMonitor, EventLog, FlightRecorder,
                       MetricsRegistry, RequestTimeline, SLOController,
                       SLOObjective, SpanTracer, export_request_tracks,
                       flight_guard, request_ids, request_timelines,
                       spans_from_chrome)
from repro.obs import dump as obs_dump
from repro.obs import schema as obs_schema
from repro.serving import DegradationLadder, Request, ServeEngine

pytestmark = pytest.mark.obs


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _reqs(prompts, max_new):
    return [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


ENGINE_KW = dict(n_lanes=2, max_len=64, dispatch_n=4, paged=True,
                 page_size=8, n_pages=10)


# ----------------------------------------------------------------------
# timelines from hand-built records (sim clock, no engine)
# ----------------------------------------------------------------------

def _clean_life(tr, uid, t0, track="node0"):
    """One gap-free request life starting at t0; returns retire time."""
    tr.add_span("admit", t0, t0 + 0.2, track=track, uid=uid, n_pages=3)
    tr.add_span("decode.dispatch", t0 + 0.2, t0 + 0.6, track=track,
                n_steps=4, uids=(uid, 99))
    tr.add_instant("first_token", t0 + 0.3, track=track, uid=uid)
    tr.add_span("decode.dispatch", t0 + 0.6, t0 + 1.0, track=track,
                n_steps=4, uids=(uid,))
    tr.add_instant("retire", t0 + 1.0, track=track, uid=uid, gen=8)
    return t0 + 1.0


def test_timeline_selection_and_derived_fields():
    tr = SpanTracer(enabled=True)
    _clean_life(tr, uid=7, t0=10.0)
    # unrelated request: must not leak into uid 7's view
    tr.add_span("admit", 0.0, 0.1, track="node0", uid=3)

    tl = RequestTimeline.from_tracer(tr, 7)
    assert [s.name for s in tl.spans] == ["admit", "decode.dispatch",
                                          "decode.dispatch"]
    assert tl.t_admit == 10.0
    assert tl.t_first_token == 10.3
    assert tl.t_retire == 11.0
    assert tl.ttft_s == pytest.approx(0.3)
    # two dispatches, 0.4 s / 4 steps each
    assert tl.tpot_mean_s == pytest.approx(0.1)
    assert tl.pages_touched == 3
    assert tl.engines == ("node0",)
    assert tl.hops == 0
    assert tl.complete and tl.gaps() == []
    assert set(tl.as_dict()) == set(obs_schema.TIMELINE_KEYS)
    # batch membership counts for uid 99 too (first dispatch only)
    assert len(RequestTimeline.from_tracer(tr, 99).spans) == 1
    assert sorted(request_ids(tr)) == [3, 7, 99]


def test_timeline_gap_rules():
    # no first token
    tr = SpanTracer(enabled=True)
    tr.add_span("admit", 0.0, 0.1, track="node0", uid=1)
    tl = RequestTimeline.from_tracer(tr, 1)
    gaps = tl.gaps()
    assert any("first_token" in g for g in gaps)
    assert any("retire" in g for g in gaps)
    assert not tl.complete

    # an evict that never came back is a gap
    tr = SpanTracer(enabled=True)
    _clean_life(tr, uid=1, t0=0.0)
    tr.add_span("preempt.evict", 0.4, 0.5, track="node0", uid=1,
                n_pages=2)
    assert any("imbalance" in g
               for g in RequestTimeline.from_tracer(tr, 1).gaps())

    # a crash migration's unmatched restore is allowed, one per hop
    tr = SpanTracer(enabled=True)
    tr.add_span("admit", 0.0, 0.2, track="node0", uid=1)
    tr.add_instant("first_token", 0.3, track="node0", uid=1)
    tr.add_span("preempt.restore", 0.5, 0.6, track="node1", uid=1,
                n_pages=2)
    tr.add_span("decode.dispatch", 0.6, 0.8, track="node1", uids=(1,))
    tr.add_instant("retire", 0.8, track="node1", uid=1, gen=4)
    tl = RequestTimeline.from_tracer(tr, 1)
    assert tl.engines == ("node0", "node1") and tl.hops == 1
    assert tl.complete, tl.gaps()
    # ...but a SECOND unmatched restore on the same hop is a gap
    tr.add_span("preempt.restore", 0.9, 1.0, track="node1", uid=1)
    assert not RequestTimeline.from_tracer(tr, 1).complete

    # decode work before admission is causally impossible
    tr = SpanTracer(enabled=True)
    _clean_life(tr, uid=1, t0=5.0)
    tr.add_span("decode.dispatch", 1.0, 1.5, track="node0", uids=(1,))
    assert any("before admission" in g
               for g in RequestTimeline.from_tracer(tr, 1).gaps())


# ----------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------

def test_chrome_trace_round_trip_preserves_durations():
    tr = SpanTracer(enabled=True)
    _clean_life(tr, uid=4, t0=2.0)
    spans, instants = spans_from_chrome(tr.export_chrome_trace())
    assert len(spans) == len(tr.spans)
    assert len(instants) == len(tr.instants)
    by_name = sorted(spans, key=lambda s: s.t0)
    orig = sorted(tr.spans, key=lambda s: s.t0)
    for a, b in zip(by_name, orig):
        assert a.name == b.name and a.track == b.track
        assert a.duration_s == pytest.approx(b.duration_s, abs=1e-9)
    # args survive, so timelines rebuild from the exported file alone
    tl = RequestTimeline.from_tracer(spans, 4, instants=instants)
    assert tl.complete and tl.ttft_s == pytest.approx(0.3)


def test_request_track_reprojection():
    tr = SpanTracer(enabled=True)
    _clean_life(tr, uid=4, t0=2.0)
    _clean_life(tr, uid=5, t0=3.0, track="node1")
    obj = export_request_tracks(request_timelines(tr))
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e.get("ph") == "M"}
    # one Perfetto track per request (uid 99 rides the uids batch)
    assert {"req/4", "req/5", "req/99"} <= names
    # each re-projected event keeps its origin engine track in args
    tracks = {e["args"].get("src_track") for e in obj["traceEvents"]
              if e.get("ph") == "X"}
    assert {"node0", "node1"} <= tracks


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("slo.alerts").inc(3)
    reg.gauge("slo.burn_rate.short", help="short burn").set(2.5)
    reg.histogram("span.admit.seconds").observe(0.25)
    text = reg.to_prometheus()
    assert "slo_alerts 3" in text
    assert "slo_burn_rate_short 2.5" in text
    assert "# HELP slo_burn_rate_short short burn" in text
    assert "span_admit_seconds" in text


# ----------------------------------------------------------------------
# flight recorder
# ----------------------------------------------------------------------

def test_flight_ring_is_bounded_and_honest():
    fr = FlightRecorder(name="t", capacity=4)
    for i in range(10):
        fr.record("span", name=f"s{i}")
    assert len(fr) == 4
    assert fr.n_seen == 10 and fr.n_dropped == 6
    assert [r["name"] for r in fr.records()] == ["s6", "s7", "s8", "s9"]


def test_flight_attach_chains_existing_hooks():
    tr = SpanTracer(enabled=True)
    log = EventLog(clock=lambda: 0.0)
    seen = []
    tr.on_span = lambda s: seen.append(("hook", s.name))
    fr = FlightRecorder(name="t").attach(tracer=tr, log=log)
    with tr.span("admit", track="e", uid=1):
        pass
    tr.instant("retire", track="e", uid=1)
    log.emit("slo.alert", short_burn=3.0)
    # the pre-existing tap still fired AND the ring captured everything
    assert ("hook", "admit") in seen
    kinds = [r["kind"] for r in fr.records()]
    assert kinds == ["span", "instant", "event"]


def test_flight_dump_load_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve.decode_dispatches").inc(5)
    fr = FlightRecorder(name="node0", capacity=8)
    fr.record("instant", name="first_token", track="node0/lane0",
              t=1.0, args={"uid": 3})
    path = fr.dump(str(tmp_path / "flight_node0.jsonl"),
                   reason="crash at dispatch 10", registry=reg,
                   dispatch=10)
    header, records = FlightRecorder.load(path)
    assert header["flight"] == "node0"
    assert header["reason"] == "crash at dispatch 10"
    assert header["dispatch"] == 10
    assert header["n_records"] == 2 and header["n_dropped"] == 0
    # the registry snapshot is appended last, so the dump carries the
    # counters at the faulting op
    assert records[-1]["kind"] == "metrics"
    assert records[0]["name"] == "first_token"
    assert all(r["kind"] in obs_schema.FLIGHT_RECORD_KINDS
               for r in records)


def test_flight_guard_dumps_on_invariant_errors_only(tmp_path,
                                                     monkeypatch):
    monkeypatch.chdir(tmp_path)

    class FakeInvariantError(AssertionError):
        pass

    fr = FlightRecorder(name="g")
    fr.record("span", name="admit")
    with pytest.raises(FakeInvariantError):
        with flight_guard(fr, op="admit"):
            raise FakeInvariantError("page leak")
    assert fr.dump_paths == [os.path.join("flight_g.jsonl")]
    header, _ = FlightRecorder.load("flight_g.jsonl")
    assert header["op"] == "admit"
    assert "FakeInvariantError" in header["reason"]

    # a non-lifecycle error passes through without dumping
    with pytest.raises(ValueError):
        with flight_guard(fr, op="admit"):
            raise ValueError("not a lifecycle fault")
    assert fr.n_dumps == 1
    # and a None recorder is a no-op guard
    with flight_guard(None, op="x"):
        pass


# ----------------------------------------------------------------------
# SLO burn-rate monitor + controller
# ----------------------------------------------------------------------

def test_burn_rate_math_and_hysteresis():
    reg = MetricsRegistry()
    mon = BurnRateMonitor(SLOObjective(tpot_s=0.01, error_budget=0.25),
                          short_window_s=2.0, long_window_s=10.0,
                          burn_threshold=2.0, clear_threshold=1.0,
                          registry=reg)
    # 50% violations everywhere: burn = 0.5 / 0.25 = 2.0 -> alert
    for i in range(10):
        mon.observe_tpot(0.02 if i % 2 else 0.005, t=float(i) * 0.2)
    assert mon.burn_rates(2.0) == (pytest.approx(2.0),
                                   pytest.approx(2.0))
    assert mon.update(2.0) is True
    assert mon.alerts_fired == 1
    assert reg["slo.violations.tpot"].value == 5
    # short window recovers -> alert clears, long window still burning
    for i in range(10):
        mon.observe_tpot(0.005, t=2.0 + float(i) * 0.2)
    short, long_ = mon.burn_rates(4.0)
    assert short == 0.0 and long_ > 0.0
    assert mon.update(4.0) is False
    # re-fire needs BOTH windows again (long alone is not enough)
    assert mon.alerts_fired == 1


def test_none_objective_drops_samples_entirely():
    mon = BurnRateMonitor(SLOObjective(tpot_s=0.01),
                          short_window_s=2.0, long_window_s=10.0)
    # TTFT carries no budget: these must NOT dilute the tpot burn rate
    for i in range(50):
        assert mon.observe_ttft(0.0, t=float(i) * 0.01) is False
    for i in range(4):
        mon.observe_tpot(0.02, t=1.0 + i * 0.01)
    short, _ = mon.burn_rates(1.1)
    assert short == pytest.approx(1.0 / 0.1)  # 100% violations / budget


def test_controller_paces_one_move_per_step():
    mon = BurnRateMonitor(SLOObjective(tpot_s=1e-9, error_budget=0.5),
                          short_window_s=2.0, long_window_s=10.0)
    ladder = DegradationLadder()
    ctl = SLOController(mon, ladder, escalate_every_s=1.0,
                        relax_every_s=2.0)
    for i in range(31):                   # violations through t=3.0
        mon.observe_tpot(0.01, t=float(i) * 0.1)
    assert ctl.step(1.0) == "escalate" and ladder.level == 1
    assert ctl.step(1.5) is None          # not due yet
    assert ctl.step(2.0) == "escalate" and ladder.level == 2
    assert ctl.step(3.0) == "escalate" and ladder.level == 3
    assert ctl.step(4.0) is None          # ladder already at the top
    # windows drain after t=13+ -> alert clears -> walk back down
    for t in (14.0, 16.0, 18.0, 20.0):
        ctl.step(t)
    assert ladder.level == 0
    assert ctl.escalated and ctl.deescalated
    assert [a for _, a, _ in ctl.actions] == ["escalate"] * 3 + \
        ["deescalate"] * 3


# ----------------------------------------------------------------------
# clock discipline + lint
# ----------------------------------------------------------------------

def test_obs_layers_share_one_monotonic_clock():
    import time
    # the clock-skew fix: EventLog used to default to time.time, which
    # skewed merged span/event timelines by the wall-clock epoch
    assert EventLog().clock is time.perf_counter
    assert SpanTracer(enabled=False).clock is time.perf_counter


def test_lint_r003_flags_obs_clock_mismatch():
    bad_kwarg = "t = SpanTracer(enabled=True, clock=time.time)\n"
    assert any(f.rule == "R003" and "clock mismatch" in f.message
               for f in lint_source(bad_kwarg))
    bad_default = textwrap.dedent("""
        def make_log(clock=time.monotonic):
            return EventLog(clock=clock)
    """)
    assert any(f.rule == "R003" and "clock mismatch" in f.message
               for f in lint_source(bad_default))
    good = textwrap.dedent("""
        def make_log(clock=time.perf_counter):
            return EventLog(clock=clock)
    """)
    assert lint_source(good) == []
    # the clock check patrols serving/ and obs/ paths too...
    assert any(f.rule == "R003" for f in lint_source(
        bad_kwarg, path="src/repro/obs/custom.py"))
    # ...but bare wall-clock CALLS stay a fleet/-only concern (benches
    # and engines legitimately read wall time for throughput numbers)
    call_only = "t0 = time.time()\n"
    assert lint_source(call_only, path="src/repro/obs/custom.py") == []
    assert any(f.rule == "R003" for f in lint_source(
        call_only, path="src/repro/fleet/custom.py"))


def test_schema_snapshot_matches_docs():
    doc_path = os.path.join(os.path.dirname(__file__), os.pardir,
                            "docs", "observability.md")
    doc = open(doc_path).read()
    missing = [n for n in obs_schema.all_names() if n not in doc]
    assert not missing, (
        f"undocumented observability names: {missing}; "
        "add them to docs/observability.md (schema snapshot)")
    assert len(obs_schema.all_names()) == len(set(obs_schema.all_names()))


# ----------------------------------------------------------------------
# dump CLI
# ----------------------------------------------------------------------

def test_dump_cli_sniffs_all_artifact_shapes(tmp_path, capsys):
    tr = SpanTracer(enabled=True)
    _clean_life(tr, uid=2, t0=1.0)
    trace_path = str(tmp_path / "trace.json")
    tr.save(trace_path)

    fr = FlightRecorder(name="node0")
    fr.record("span", name="admit", track="node0", t0=0.0, t1=0.1,
              args={"uid": 2})
    flight_path = fr.dump(str(tmp_path / "flight_node0.jsonl"),
                          reason="sanity")

    pages_path = str(tmp_path / "pages.jsonl")
    with open(pages_path, "w") as f:
        f.write(json.dumps({"op": "alloc", "page": 1}) + "\n")
        f.write(json.dumps({"op": "free", "page": 1}) + "\n")

    assert obs_dump.sniff(trace_path) == "trace"
    assert obs_dump.sniff(flight_path) == "flight"
    assert obs_dump.sniff(pages_path) == "pages"

    assert obs_dump.main([trace_path, flight_path, pages_path]) == 0
    out = capsys.readouterr().out
    assert "1 request(s)" in out or "2 request(s)" in out
    assert "flight dump of engine 'node0'" in out
    assert "alloc=1" in out and "free=1" in out

    bogus = str(tmp_path / "bogus.txt")
    open(bogus, "w").write("not telemetry")
    assert obs_dump.main([bogus]) == 1


# ----------------------------------------------------------------------
# engine integration: full stack on, nothing observable changes
# ----------------------------------------------------------------------

def test_engine_full_stack_exactness_and_gap_free_timelines(
        small_model, tmp_path, monkeypatch):
    cfg, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(4)]

    plain = _reqs(prompts, 6)
    ServeEngine(cfg, params, **ENGINE_KW).run(plain)

    monkeypatch.chdir(tmp_path)   # any flight dump lands here
    reg = MetricsRegistry()
    tracer = SpanTracer(enabled=True, registry=reg)
    mon = BurnRateMonitor(SLOObjective(ttft_s=60.0, tpot_s=1.0),
                          registry=reg)
    ctl = SLOController(mon, DegradationLadder())
    flight = FlightRecorder(name="serve")
    observed = _reqs(prompts, 6)
    eng = ServeEngine(cfg, params, tracer=tracer, registry=reg,
                      flight=flight, slo=ctl, **ENGINE_KW)
    eng.run(observed)

    # the stack is a mirror: streams identical, ladder untouched
    assert [r.generated for r in observed] == [r.generated
                                               for r in plain]
    assert ctl.ladder.level == 0 and not ctl.escalated

    # every request reconstructs a gap-free timeline with real latencies
    tls = request_timelines(tracer)
    assert sorted(tls) == [0, 1, 2, 3]
    for uid, tl in sorted(tls.items()):
        assert tl.complete, (uid, tl.gaps())
        assert tl.ttft_s is not None and tl.ttft_s > 0
        assert tl.tpot_series, "dispatch spans must carry uids"
    # SLO observations happened on the engine's own clock
    assert len(mon.short) > 0
    assert eng._admit_t == {}     # every TTFT mark was consumed
    # the flight ring shadowed the tracer the whole run
    assert any(r["kind"] == "span" and r["name"] == "decode.dispatch"
               for r in flight.records())
    # retire instants carry the generated-token count
    retires = [e for e in tracer.instants if e.name == "retire"]
    assert retires and all(e.args.get("gen") == 6 for e in retires)


@pytest.mark.slow
def test_crash_replay_produces_cross_engine_timelines(small_model,
                                                      tmp_path):
    from repro.fleet.execution import run_trace_with_faults
    from repro.fleet.workload import LengthDist, poisson_trace

    cfg, params = small_model
    trace = poisson_trace(2.0, 6.0, seed=3,
                          prompt=LengthDist(12, cv=0.3),
                          gen=LengthDist(14, cv=0.4))
    reg = MetricsRegistry()
    tracer = SpanTracer(enabled=True, registry=reg)
    ctl = SLOController(
        BurnRateMonitor(SLOObjective(tpot_s=1e-9, error_budget=0.05),
                        registry=reg),
        DegradationLadder())
    res = run_trace_with_faults(
        trace, cfg, params, crash_at_dispatch=10, checkpoint_every=3,
        transient_dispatches=(2,), n_lanes=2, max_len=32, dispatch_n=4,
        page_size=8, seed=5, tracer=tracer, registry=reg,
        flight_dir=str(tmp_path), slo=ctl)

    assert res.crashes == 1 and len(res.flight_dumps) == 1
    header, records = FlightRecorder.load(res.flight_dumps[0])
    assert "crash" in header["reason"] and records

    tls = request_timelines(tracer)
    assert tls and all(tl.complete for tl in tls.values()), {
        u: tl.gaps() for u, tl in tls.items() if not tl.complete}
    # checkpointed lanes span the dead board AND the survivor
    for uid in res.checkpointed_uids:
        assert tls[uid].engines == ("node0", "node1")
    assert ctl.escalated          # the impossible objective paged
