"""Per-architecture smoke tests: reduced config, one forward/train step.

Each assigned architecture gets its SMOKE config instantiated on CPU,
runs a forward pass and one loss/grad evaluation, and asserts output
shapes and finiteness (no NaNs).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.common import pad_vocab

B, S = 2, 64


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            ks[3], (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + ["qwen2.5-1.5b"])
def test_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = model.forward(params, batch)
    assert logits.shape == (B, S, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(
        jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size, logits,
                  0.0))))

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-780m", "hymba-1.5b",
                                  "whisper-base"])
def test_decode_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, 16, cfg.d_model))
        enc = model.encode(params, frames)
        cache = model.init_cache(params, B, max_len=32, enc=enc)
    else:
        cache = model.init_cache(params, B, max_len=32)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache = model.decode_step(params, cache, tok)
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size])))
    assert int(cache["len"][0]) == 1
