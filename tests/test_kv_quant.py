"""int8 KV-cache decode: correctness vs the bf16 cache (beyond-paper C4)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.transformer import init_cache, lm_decode_step


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2.5-1.5b"])
def test_int8_kv_tracks_dense(arch):
    cfg = get_config(arch, smoke=True)
    cfg_q = dataclasses.replace(cfg, kv_quant="int8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)

    def run(c):
        cache = init_cache(c, 2, 24)
        logits = None
        for t in range(tokens.shape[1]):
            logits, cache = lm_decode_step(params, c, cache, tokens[:, t])
        return jax.nn.log_softmax(logits[:, :cfg.vocab_size], axis=-1)

    dense = run(cfg)
    quant = run(cfg_q)
    # int8 KV error stays small in log-prob space
    assert float(jnp.max(jnp.abs(dense - quant))) < 0.15
    # and top-1 predictions agree
    assert bool(jnp.all(jnp.argmax(dense, -1) == jnp.argmax(quant, -1)))


def test_int8_cache_layout():
    cfg = dataclasses.replace(get_config("olmo-1b", smoke=True),
                              kv_quant="int8")
    cache = init_cache(cfg, 2, 16)
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].shape == cache["k"].shape[:-1] + (1,)
    # bytes: int8 KV + f32/ D scales ~= 0.53x of bf16
    kv_b = cache["k"].nbytes + cache["k_scale"].nbytes
    dense_b = cache["k"].size * 2
    assert kv_b / dense_b < 0.6
