"""Core-layer tests: device profiles, path policy, perf model vs the
paper's stated claims, energy/cost model, HLO collective parser."""

import jax.numpy as jnp
import pytest

from repro.core.compute_path import PathPolicy, matmul_descriptor
from repro.core.device_profile import (A100_40G, CMP_170HX, CMP_170HX_NOFMA,
                                       TPU_V5E, Path, get_profile)
from repro.core.energy import estimate_sales
from repro.core.hlo_analysis import collective_bytes, op_census
from repro.core.perf_model import InferencePerfModel
from repro.core.roofline import RooflineTerms, analyze

FMTS = ["f32", "f16", "q8_0", "q6_k", "q4_k", "q2_k"]


# ----------------------------------------------------------------------
# paper claims (the reproduction gate)
# ----------------------------------------------------------------------

class TestPaperClaims:
    def test_fp32_recovery_over_15x(self):
        """Abstract: 'FP32 performance exceeds 15x the original'."""
        default = CMP_170HX.throughput("f32", Path.FMA)
        nofma = CMP_170HX_NOFMA.throughput("f32", Path.MUL_ADD)
        assert nofma / default > 15.0
        assert abs(default - 0.39) < 0.01      # 1/32 of 12.63
        assert 0.4 < nofma / 12.63 < 0.6       # ~half of theoretical

    def test_fp16_unaffected_by_fma(self):
        assert CMP_170HX.throughput("f16", Path.MUL_ADD) == \
            CMP_170HX_NOFMA.throughput("f16", Path.MUL_ADD)

    def test_fp64_no_recovery(self):
        """FP64: ~1/32 default, halves again without FMA."""
        assert CMP_170HX_NOFMA.throughput("f64", Path.MUL_ADD) < \
            CMP_170HX.throughput("f64", Path.FMA)

    def test_prefill_band_14_45(self):
        m = InferencePerfModel(CMP_170HX_NOFMA)
        for fmt in FMTS:
            frac = (m.prefill(fmt).tokens_per_s
                    / m.theoretical_prefill_tps(fmt))
            assert 0.14 <= frac <= 0.45, (fmt, frac)

    def test_decode_bands(self):
        md = InferencePerfModel(CMP_170HX)
        mn = InferencePerfModel(CMP_170HX_NOFMA)
        for fmt in FMTS:
            fd = md.decode(fmt).tokens_per_s / md.theoretical_decode_tps(fmt)
            fn = mn.decode(fmt).tokens_per_s / mn.theoretical_decode_tps(fmt)
            assert 0.35 <= fd <= 0.80, (fmt, fd)   # paper: 39-78%
            assert 0.50 <= fn <= 0.80, (fmt, fn)   # paper: 50-78%

    def test_q2k_prefill_gain_231pct(self):
        md = InferencePerfModel(CMP_170HX)
        mn = InferencePerfModel(CMP_170HX_NOFMA)
        gains = {f: mn.prefill(f).tokens_per_s / md.prefill(f).tokens_per_s
                 for f in FMTS}
        assert max(gains, key=gains.get) == "q2_k"
        assert 2.0 < gains["q2_k"] < 2.6           # paper: 2.31x
        assert gains["f32"] == pytest.approx(1.0)
        assert gains["f16"] == pytest.approx(1.0)

    def test_quantized_gain_ordering(self):
        """Smaller sub-blocks => more FP32 epilogue => bigger noFMA gain."""
        md = InferencePerfModel(CMP_170HX)
        mn = InferencePerfModel(CMP_170HX_NOFMA)
        g = {f: mn.prefill(f).tokens_per_s / md.prefill(f).tokens_per_s
             for f in ("q8_0", "q6_k", "q2_k")}
        assert g["q2_k"] > g["q6_k"] > g["q8_0"] > 1.0

    def test_decode_memory_bound_on_bandwidth_rich(self):
        m = InferencePerfModel(CMP_170HX)
        for fmt in ("f32", "f16", "q8_0"):
            assert m.decode(fmt).bound == "memory"

    def test_efficiency_comparable_to_a100(self):
        for fmt in ("f32", "f16", "q8_0"):
            ec = InferencePerfModel(CMP_170HX).decode(fmt).tokens_per_joule
            ea = InferencePerfModel(A100_40G).decode(fmt).tokens_per_joule
            assert 0.6 <= ec / ea <= 1.2, (fmt, ec / ea)

    def test_sales_estimates_match_table_1_2(self):
        assert estimate_sales("A")["total"] == pytest.approx(582714, rel=.01)
        assert estimate_sales("B")["total"] == pytest.approx(640127, rel=.01)
        assert estimate_sales("C")["total"] == pytest.approx(463133, rel=.01)


# ----------------------------------------------------------------------
# path policy
# ----------------------------------------------------------------------

def test_policy_reroutes_on_crippled_sku():
    desc = matmul_descriptor(512, 512, 4096, "f32")
    assert PathPolicy(CMP_170HX).decide(desc).variant == "mul_add"
    assert PathPolicy(TPU_V5E).decide(desc).variant == "fma"


def test_policy_force_variant():
    desc = matmul_descriptor(64, 64, 256, "f32")
    d = PathPolicy(CMP_170HX, force_variant="fma").decide(desc)
    assert d.variant == "fma"


def test_profile_registry():
    assert get_profile("cmp-170hx").hbm_capacity_gib == 8.0
    with pytest.raises(KeyError):
        get_profile("rtx-5090")


# ----------------------------------------------------------------------
# HLO analysis + roofline
# ----------------------------------------------------------------------

_HLO_SAMPLE = """
  %ag = f32[8,128]{1,0} all-gather(f32[1,128]{1,0} %p0), replica_groups={}
  %ar = bf16[256]{0} all-reduce(bf16[256]{0} %x), to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(f32[16,64]{1,0} %y), dimensions={0}
  %a2a = f32[4,32]{1,0} all-to-all(f32[4,32]{1,0} %z), dimensions={0}
  %cp = u8[1024]{0} collective-permute(u8[1024]{0} %w)
  %d = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
"""


def test_collective_bytes_parser():
    stats = collective_bytes(_HLO_SAMPLE)
    assert stats.bytes_by_kind["all-gather"] == 8 * 128 * 4
    assert stats.bytes_by_kind["all-reduce"] == 256 * 2
    assert stats.bytes_by_kind["reduce-scatter"] == 2 * 64 * 4
    assert stats.bytes_by_kind["all-to-all"] == 4 * 32 * 4
    assert stats.bytes_by_kind["collective-permute"] == 1024
    assert stats.total_count == 5
    census = op_census(_HLO_SAMPLE)
    assert census["dot"] == 1


def test_roofline_terms():
    r = analyze(cell="x/y/16x16", chips=256,
                hlo_flops=1e18, hlo_bytes=1e15, collective_bytes=1e14,
                model_flops=7e17)
    # compute: 1e18 / (256 * 197e12) = 19.8ms
    assert r.t_compute_s == pytest.approx(1e18 / (256 * 197e12))
    assert r.t_memory_s == pytest.approx(1e15 / (256 * 819e9))
    assert r.t_collective_s == pytest.approx(1e14 / (256 * 50e9))
    assert r.dominant == "compute"
    assert r.useful_flops_ratio == pytest.approx(0.7)
    assert 0.0 < r.roofline_fraction <= 1.0
