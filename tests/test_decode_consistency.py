"""Serving invariant: incremental decode == teacher-forced forward.

For every family with a decode path, stepping the cached decoder token by
token must reproduce the logits of the full (parallel) forward pass.
This is THE correctness property of the serving engine (KV cache, RoPE
positions, SSM state carry, ring buffers).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.transformer import init_cache, lm_decode_step

B, S = 2, 24


def _decode_logits_seq(model, params, tokens, max_len):
    cfg = model.cfg
    cache = init_cache(cfg, tokens.shape[0], max_len)
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = lm_decode_step(params, cfg, cache, tokens[:, t])
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # (B, S, V)


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2.5-1.5b", "mamba2-780m",
                                  "hymba-1.5b", "moonshot-v1-16b-a3b"])
def test_incremental_matches_parallel(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # capacity drops differ between batched and one-token dispatch;
        # equivalence is exact only in the no-drop regime.
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    full_logits = model.forward(params, {"tokens": tokens})
    inc_logits = _decode_logits_seq(model, params, tokens, max_len=S + 4)
    # compare log-probabilities over the real vocab (padding masked)
    fl = jax.nn.log_softmax(full_logits[..., :cfg.vocab_size], axis=-1)
    il = jax.nn.log_softmax(inc_logits[..., :cfg.vocab_size], axis=-1)
    err = float(jnp.max(jnp.abs(fl - il)))
    # MoE tolerance is looser: token-choice capacity differs between the
    # batched (many tokens) and incremental (one token) dispatch.
    tol = 0.2 if cfg.family == "moe" else 2e-2
    assert err < tol, f"{arch}: decode/forward divergence {err}"


def test_sliding_window_ring_buffer():
    """Hymba ring cache: decoding past the window keeps exactness for the
    last `window` positions (tokens outside the window are forgotten by
    construction)."""
    cfg = get_config("hymba-1.5b", smoke=True)  # window=32
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = cfg.sliding_window + 8  # exceed the window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0,
                                cfg.vocab_size)
    full = model.forward(params, {"tokens": tokens})
    inc = _decode_logits_seq(model, params, tokens, max_len=n)
    fl = jax.nn.log_softmax(full[..., :cfg.vocab_size], axis=-1)
    il = jax.nn.log_softmax(inc[..., :cfg.vocab_size], axis=-1)
    err = float(jnp.max(jnp.abs(fl[:, -4:] - il[:, -4:])))
    assert err < 2e-2, f"ring-buffer divergence {err}"


def test_whisper_decode_consistency():
    cfg = get_config("whisper-base", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 16, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    enc = model.encode(params, frames)
    from repro.models.whisper import decode_forward, init_whisper_cache, \
        whisper_decode_step
    full = decode_forward(params, tokens, enc, cfg)
    cache = init_whisper_cache(params, enc, cfg, B, S + 4)
    outs = []
    for t in range(S):
        logits, cache = whisper_decode_step(params, cfg, cache, tokens[:, t])
        outs.append(logits)
    inc = jnp.stack(outs, axis=1)
    fl = jax.nn.log_softmax(full[..., :cfg.vocab_size], axis=-1)
    il = jax.nn.log_softmax(inc[..., :cfg.vocab_size], axis=-1)
    assert float(jnp.max(jnp.abs(fl - il))) < 2e-2
