"""Static analysis & sanitizer layer: lint rules, page-lifecycle
sanitizer, lane-lifecycle interleaving checker.

Four layers of pins:

* lint -- each rule R001-R005 fires on a minimal synthetic snippet and
  stays quiet on the idiomatic fix; suppressions need a reason; the
  JSON report is machine-readable; and the REPO'S OWN ``src/`` tree is
  clean (zero unsuppressed findings) -- the ``make lint`` gate;
* invariants -- :class:`InvariantError` subclasses ``AssertionError``
  (pre-existing ``pytest.raises(AssertionError)`` sites keep working)
  but carries structured context, and the allocator's promoted checks
  still fire under ``python -O`` (subprocess pin);
* sanitizer -- every violation class is detected from a scripted op
  stream with the RIGHT code (seeded-mutation tests), strict mode
  raises at the faulting op while replay collects, a real sanitized
  engine run (prefill / prefix hits / CoW / evict / restore) is clean
  and token-exact vs the unsanitized engine, and the recorded
  ``pages.jsonl`` stream round-trips through the offline replay;
* interleave -- the bounded explorer sweeps the admit / hit / cow /
  evict / restore / retire / flush lifecycle exhaustively without a
  violation against the real :class:`PagePool`, and CATCHES the seeded
  refcount-blind allocator with a deterministic op-trace reproducer.

Plus the determinism satellite: the fleet report is byte-identical
across ``PYTHONHASHSEED`` values (subprocess pin) now that every
set/dict-view iteration feeding event order is sorted.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.invariants import InvariantError, invariant
from repro.analysis.lint import (RULES, lint_paths, lint_source, report,
                                 main as lint_main)
from repro.analysis.sanitizer import (VIOLATIONS, PageSanitizer,
                                      SanitizerError, load_jsonl)

pytestmark = pytest.mark.analysis

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def _src_env(**extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.update(extra)
    return env


def _open_rules(findings):
    return [f.rule for f in findings if not f.suppressed]


# ----------------------------------------------------------------------
# lint: one positive + one negative snippet per rule
# ----------------------------------------------------------------------

def test_r001_bare_assert_flagged_invariant_clean():
    bad = "def f(x):\n    assert x > 0, 'positive'\n"
    assert _open_rules(lint_source(bad)) == ["R001"]
    good = ("from repro.analysis.invariants import invariant\n"
            "def f(x):\n    invariant(x > 0, 'positive', x=x)\n")
    assert lint_source(good) == []


def test_r002_host_sync_inside_dispatch_region():
    bad = textwrap.dedent("""
        import jax
        def _step(carry, x):
            return carry, float(x.item())
        step = jax.jit(_step)
    """)
    assert _open_rules(lint_source(bad)) == ["R002", "R002"]
    # a lambda handed to lax.scan is a dispatch region too
    lam = textwrap.dedent("""
        import jax
        out = jax.lax.scan(lambda c, x: (c, x.block_until_ready()), 0, xs)
    """)
    assert _open_rules(lint_source(lam)) == ["R002"]
    # the same sync OUTSIDE any dispatch region is host-side bookkeeping
    good = "def summarize(x):\n    return x.item()\n"
    assert lint_source(good) == []


def test_r003_unseeded_randomness_and_wallclock():
    bad = textwrap.dedent("""
        import random, time
        import numpy as np
        def jitter():
            a = random.random()
            b = np.random.rand(3)
            t = time.perf_counter()
            return a, b, t
    """)
    assert _open_rules(lint_source(bad)) == ["R003", "R003", "R003"]
    good = textwrap.dedent("""
        import numpy as np
        def jitter(seed):
            rng = np.random.default_rng(seed)
            return rng.random(3)
    """)
    assert lint_source(good) == []


def test_r004_bare_runtime_error_raise():
    bad = "def admit(q):\n    raise RuntimeError('queue deadlocked')\n"
    assert _open_rules(lint_source(bad)) == ["R004"]
    good = textwrap.dedent("""
        from repro.serving.resilience import AdmissionRejected
        def admit(q):
            raise AdmissionRejected(uid=1, reason='never_admissible')
    """)
    assert lint_source(good) == []
    # a bare re-raise inside a handler is not a bare RuntimeError
    assert lint_source("try:\n    f()\nexcept ValueError:\n    raise\n") == []


def test_r005_unsorted_set_and_dictview_iteration():
    bad = textwrap.dedent("""
        pending = {3, 1, 2}
        def drain(heap):
            for uid in pending:
                heap.push(uid)
    """)
    assert _open_rules(lint_source(bad)) == ["R005"]
    # comprehensions are iteration sites too
    comp = "live = set()\nout = [x for x in live]\n"
    assert _open_rules(lint_source(comp)) == ["R005"]
    # dict views feed the event heap in FleetSim
    view = "def tick(node):\n    for s in node.items():\n        s.step()\n"
    assert _open_rules(lint_source(view)) == ["R005"]
    good = textwrap.dedent("""
        pending = {3, 1, 2}
        def drain(heap, node):
            for uid in sorted(pending):
                heap.push(uid)
            eligible = sorted(node.values(), key=lambda s: s.uid)
            for s in eligible:
                s.step()
    """)
    assert lint_source(good) == []


def test_suppression_requires_reason():
    reasoned = ("def f(x):\n"
                "    assert x  # lint: ok R001 tier-0 scaffolding\n")
    (f,) = lint_source(reasoned)
    assert f.suppressed and f.reason == "tier-0 scaffolding"
    # the line ABOVE carries the suppression too
    above = ("# lint: ok R001 tier-0 scaffolding\n"
             "assert True\n")
    (f,) = lint_source(above)
    assert f.suppressed
    # a reasonless suppression stays an unsuppressed finding
    bare = "def f(x):\n    assert x  # lint: ok R001\n"
    (f,) = lint_source(bare)
    assert not f.suppressed
    # a suppression for a DIFFERENT rule does not apply
    wrong = "def f(x):\n    assert x  # lint: ok R003 not this rule\n"
    (f,) = lint_source(wrong)
    assert not f.suppressed


def test_json_report_is_machine_readable():
    doc = json.loads(report(lint_source("assert True\n"), as_json=True))
    assert doc["n_findings"] == doc["n_unsuppressed"] == 1
    (f,) = doc["findings"]
    assert f["rule"] == "R001" and f["line"] == 1
    assert set(f) == {"rule", "path", "line", "message", "suppressed",
                      "reason"}
    assert set(doc["rules"]) == set(RULES)
    # a syntax error is reported, not raised
    (f,) = lint_source("def broken(:\n")
    assert f.rule == "PARSE"


def test_repo_src_is_lint_clean():
    """The ``make lint`` gate: zero unsuppressed findings over src/,
    and every suppression that holds the line carries a reason."""
    findings = lint_paths([str(SRC)])
    assert _open_rules(findings) == [], report(findings)
    assert all(f.reason for f in findings if f.suppressed)


def test_lint_cli_exit_status(capsys):
    assert lint_main([str(SRC), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["n_unsuppressed"] == 0


# ----------------------------------------------------------------------
# invariants: structured, always-on
# ----------------------------------------------------------------------

def test_invariant_error_is_structured_assertion_error():
    invariant(True, "holds")                 # truthy: no raise
    with pytest.raises(AssertionError) as ei:
        invariant(False, "refcount out of sync", page=3, ref=0)
    err = ei.value
    assert isinstance(err, InvariantError)
    assert err.message == "refcount out of sync"
    assert err.context == {"page": 3, "ref": 0}
    assert "page=3" in str(err)


def test_pagepool_misuse_raises_invariant_error():
    from repro.serving import PagePool

    pool = PagePool(4, 8)
    with pytest.raises(InvariantError):
        pool.alloc(1)                        # no reservation
    assert pool.reserve(1)
    (p,) = pool.alloc(1)
    pool.free([p])
    with pytest.raises(InvariantError) as ei:
        pool.free([p])                       # double free
    assert ei.value.context.get("page") == p
    with pytest.raises(InvariantError):
        pool.unreserve(1)                    # nothing outstanding


@pytest.mark.slow
def test_invariants_survive_assertions_disabled():
    """The promoted allocator checks fire under ``python -O`` (where a
    bare assert is stripped to nothing)."""
    code = textwrap.dedent("""
        assert False, "-O is not active"   # stripped: proves -O mode
        from repro.analysis.invariants import InvariantError
        from repro.serving.engine import PagePool
        pool = PagePool(2, 8)
        try:
            pool.alloc(1)
        except InvariantError:
            pass
        else:
            raise SystemExit("alloc without reservation not caught")
        assert pool.reserve(1) or True
        pool.reserve(1)
        (p,) = pool.alloc(1)
        pool.free([p])
        try:
            pool.free([p])
        except InvariantError:
            print("INVARIANTS_ON")
        else:
            raise SystemExit("double free not caught under -O")
    """)
    r = subprocess.run([sys.executable, "-O", "-c", code],
                       capture_output=True, text=True, env=_src_env())
    assert r.returncode == 0, r.stderr
    assert "INVARIANTS_ON" in r.stdout


# ----------------------------------------------------------------------
# sanitizer: seeded mutations, one per violation class
# ----------------------------------------------------------------------

def _shadow(n_pages=4, strict=False):
    san = PageSanitizer(strict=strict)
    san.record("init", n_pages=n_pages, page_size=8, scratch=n_pages)
    return san


def _codes(san):
    return [v.code for v in san.violations]


def test_sanitizer_detects_double_free():
    san = _shadow()
    san.record("reserve", n=1, ok=True)
    san.record("alloc", pages=[0], holder=0)
    san.record("free", pages=[0], holder=0)
    assert san.clean
    san.record("free", pages=[0], holder=0)
    assert _codes(san) == ["DOUBLE_FREE"]


def test_sanitizer_detects_scratch_page_use():
    san = _shadow(n_pages=4)                 # scratch id is 4
    san.record("reserve", n=1, ok=True)
    san.record("alloc", pages=[4], holder=0)     # allocator hands it out
    san.record("write", lane=0, pages=[4], kind="decode")
    san.record("capture", lane=0, pages=[1, 4])
    san.record("free", pages=[4], holder=0)
    assert _codes(san) == ["SCRATCH_PAGE"] * 4


def test_sanitizer_detects_missing_cow_write():
    """The donor may append to its shared partial page; any OTHER
    holder must split first.  The cow + cow_copy path stays clean."""
    san = _shadow()
    san.record("reserve", n=2, ok=True)
    san.record("alloc", pages=[0, 1], holder=0)
    san.record("share", pages=[0], holder=1)
    san.record("map", lane=1, pages=[0])
    san.record("write", lane=0, pages=[0], kind="decode")   # the donor
    assert san.clean
    san.record("write", lane=1, pages=[0], kind="decode")   # no CoW!
    assert _codes(san) == ["WRITE_SHARED_NO_COW"]
    # the legal sequence: reserve -> cow split -> write the fresh copy
    san.record("reserve", n=1, ok=True)
    san.record("cow", old=0, new=2, holder=1)
    san.record("write", lane=1, pages=[2], kind="cow_copy")
    assert _codes(san) == ["WRITE_SHARED_NO_COW"]           # no new ones


def test_sanitizer_detects_unshared_map_and_write():
    san = _shadow()
    san.record("reserve", n=1, ok=True)
    san.record("alloc", pages=[0], holder=0)
    san.record("map", lane=1, pages=[0])     # lane 1 holds no reference
    san.record("write", lane=1, pages=[0], kind="decode")
    assert _codes(san) == ["ALIAS_EXCLUSIVE", "ALIAS_EXCLUSIVE"]


def test_sanitizer_detects_accounting_misuse():
    san = _shadow()
    san.record("unreserve", n=1)             # nothing promised
    san.record("alloc", pages=[0], holder=0)     # never reserved
    san.record("share", pages=[3], holder=1)     # page 3 is free
    san.record("reserve", n=1, ok=True)
    san.record("cow", old=0, new=1, holder=0)    # ref 1: nothing shared
    san.record("write", lane=0, pages=[2], kind="decode")  # unallocated
    assert _codes(san) == ["RESERVE_UNDERFLOW", "ALLOC_UNRESERVED",
                           "SHARE_FREE", "COW_EXCLUSIVE", "UNKNOWN_PAGE"]
    assert all(code in VIOLATIONS for code in _codes(san))


def test_sanitizer_strict_raises_at_faulting_op():
    san = _shadow(strict=True)
    san.record("reserve", n=1, ok=True)
    san.record("alloc", pages=[0], holder=0)
    san.record("free", pages=[0], holder=0)
    with pytest.raises(SanitizerError) as ei:
        san.record("free", pages=[0], holder=0)
    assert ei.value.violation.code == "DOUBLE_FREE"
    assert isinstance(ei.value, AssertionError)   # InvariantError family
    assert ei.value.violation.as_dict()["op"]["op"] == "free"


def test_sanitizer_replay_collects_instead_of_raising():
    ops = [
        {"op": "init", "n_pages": 4, "page_size": 8, "scratch": 4},
        {"op": "reserve", "n": 1, "ok": True},
        {"op": "alloc", "pages": [0], "holder": 0},
        {"op": "free", "pages": [0], "holder": 0},
        {"op": "free", "pages": [0], "holder": 0},
        {"op": "free", "pages": [0], "holder": 0},
    ]
    san = PageSanitizer.replay(ops)          # no raise despite 2 faults
    assert _codes(san) == ["DOUBLE_FREE", "DOUBLE_FREE"]
    assert san.ops_seen == len(ops)


def test_sanitizer_crosscheck_catches_shadow_pool_divergence():
    from repro.serving import PagePool

    pool = PagePool(4, 8)
    san = PageSanitizer(strict=False)
    pool.monitor = san
    san.record("init", n_pages=4, page_size=8, scratch=4)
    pool.reserve(2)
    pages = pool.alloc(2, holder=0)
    san.crosscheck(pool)
    assert san.clean                         # mirror agrees
    pool._free.append(pages[0])              # tamper behind the monitor
    san.crosscheck(pool)
    assert "CONSERVATION" in _codes(san)


def test_sanitizer_jsonl_round_trip(tmp_path):
    from repro.obs.events import EventLog

    log = EventLog(clock=lambda: 0.0)
    live = PageSanitizer(strict=True, log=log)
    live.record("init", n_pages=4, page_size=8, scratch=4)
    live.record("reserve", n=2, ok=True)
    live.record("alloc", pages=[0, 1], holder=0)
    live.record("share", pages=[0], holder="cache")
    live.record("free", pages=[0, 1], holder=0)
    live.record("free", pages=[0], holder="cache")
    path = tmp_path / "pages.jsonl"
    n = log.dump(path, prefix="page")
    assert n == live.ops_seen == 6
    replayed = PageSanitizer.replay(load_jsonl(path))
    assert replayed.clean and replayed.ops_seen == n
    # corrupting the stream localizes the fault on replay
    records = load_jsonl(path)
    records.append({"op": "free", "pages": [1], "holder": 0})
    bad = PageSanitizer.replay(records)
    assert _codes(bad) == ["DOUBLE_FREE"]


# ----------------------------------------------------------------------
# sanitizer inline: a real engine run must be clean AND exact
# ----------------------------------------------------------------------

PAGE = 8
ENGINE_KW = dict(n_lanes=2, max_len=32, dispatch_n=4, paged=True,
                 page_size=PAGE, rng_seed=7)


@pytest.fixture(scope="module")
def small_model():
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _family(cfg, head_len=2 * PAGE, tails=(4, 6), seed=11):
    import numpy as np

    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, head_len, dtype=np.int32)
    return [np.concatenate(
                [head, rng.integers(0, cfg.vocab_size, t, dtype=np.int32)])
            for t in tails]


def _serve(cfg, params, prompts, max_new, **kw):
    from repro.serving import Request, ServeEngine

    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng = ServeEngine(cfg, params, **kw)
    eng.run(reqs)
    return [tuple(r.generated) for r in reqs], eng


def test_engine_sanitize_off_is_one_attr_check(small_model):
    """OFF is the default and costs one attribute: no sanitizer object,
    no pool monitor."""
    from repro.serving import ServeEngine

    cfg, params = small_model
    eng = ServeEngine(cfg, params, **ENGINE_KW)
    assert eng._sanitizer is None and eng.pool.monitor is None


def test_engine_sanitized_run_clean_and_token_exact(small_model):
    """Prefill + prefix hits + CoW under ``sanitize=True``: zero
    violations, streams identical to the unsanitized engine."""
    cfg, params = small_model
    prompts = _family(cfg, tails=(4, 6, 8))
    kw = dict(ENGINE_KW, prefix_sharing=True)
    base, _ = _serve(cfg, params, prompts, 6, **kw)
    shared, eng = _serve(cfg, params, prompts, 6, sanitize=True, **kw)
    assert shared == base
    san = eng._sanitizer
    assert san is not None and eng.pool.monitor is san
    assert san.clean and san.ops_seen > 0
    assert eng.stats["prefix_hits"] >= 1     # CoW path actually ran
    eng.prefix_cache.flush()
    eng.pool.check()
    san.crosscheck(eng.pool)
    assert san.clean and eng.pool.n_in_use == 0


def test_engine_sanitized_evict_restore_clean(small_model):
    """Mid-decode evict -> restore of a prefix-hit lane under the
    strict sanitizer: capture/restore ops all legal, mirror still in
    lockstep at the end."""
    from repro.serving import Request, ServeEngine

    cfg, params = small_model
    donor, consumer = _family(cfg)
    eng = ServeEngine(cfg, params, prefix_sharing=True, sanitize=True,
                      **ENGINE_KW)
    dreq = Request(uid=0, prompt=donor.copy(), max_new_tokens=10)
    eng.run([dreq])                          # retire donor, warm cache
    creq = Request(uid=1, prompt=consumer.copy(), max_new_tokens=10)
    assert eng.admit(creq)
    assert eng.stats["prefix_hits"] == 1
    eng.decode_n()
    lane = next(i for i, r in enumerate(eng.lane_req) if r is creq)
    ckpt = eng.evict(lane)
    assert eng.restore(ckpt)
    while eng.live_lanes():
        eng.decode_n()
    san = eng._sanitizer
    assert san.clean
    eng.prefix_cache.flush()
    eng.pool.check()
    san.crosscheck(eng.pool)
    assert san.clean and eng.pool.n_in_use == 0


def test_engine_offline_replay_of_recorded_run(small_model, tmp_path):
    """The inline op stream dumped as ``pages.jsonl`` replays clean
    offline; a corrupted record is localized to its violation."""
    from repro.obs.events import EventLog
    from repro.serving import Request, ServeEngine

    cfg, params = small_model
    log = EventLog(clock=lambda: 0.0)
    eng = ServeEngine(cfg, params, prefix_sharing=True, sanitize=True,
                      **ENGINE_KW)
    eng._sanitizer.log = log
    log.emit("page.init", n_pages=eng.pool.n_pages, page_size=PAGE,
             scratch=eng._scratch_page)
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=6)
            for i, p in enumerate(_family(cfg))]
    eng.run(reqs)
    eng.prefix_cache.flush()

    path = tmp_path / "pages.jsonl"
    n = log.dump(path, prefix="page")
    assert n == len(log) > 0
    records = load_jsonl(path)
    san = PageSanitizer.replay(records)
    assert san.clean and san.ops_seen == n
    # every page went back: one more free of ANY page is a double free
    records.append({"op": "free", "pages": [0], "holder": 0})
    bad = PageSanitizer.replay(records)
    assert _codes(bad) == ["DOUBLE_FREE"]


# ----------------------------------------------------------------------
# interleaving checker
# ----------------------------------------------------------------------

def test_interleave_exhaustive_sweep_is_clean():
    """Every legal admit/hit/cow/decode/evict/restore/retire/flush
    interleaving to depth 4 holds the pool + shadow invariants."""
    from repro.analysis import interleave

    visited = interleave.explore(
        lambda: interleave.LifecycleHarness(), depth=4)
    assert visited > 100                     # a real state space


@pytest.mark.slow
def test_interleave_exhaustive_sweep_depth5_is_clean():
    from repro.analysis import interleave

    assert interleave.explore(
        lambda: interleave.LifecycleHarness(), depth=5) > 500


def test_interleave_catches_refcount_blind_allocator():
    """The seeded bug double -- ``free`` ignores refcounts -- is legal
    in share-free orderings and must be caught the moment an
    interleaving shares a page and one holder releases.  The raised
    trace is the reproducer."""
    from repro.analysis import interleave

    with pytest.raises(interleave.InterleavingBug) as ei:
        interleave.explore(
            lambda: interleave.LifecycleHarness(
                pool_cls=interleave.RefcountBlindPool),
            depth=4)
    bug = ei.value
    assert len(bug.trace) >= 2               # needs a share first
    names = [name for name, _ in bug.trace]
    assert names[0] in ("admit", "hit")      # something shared a page
    assert "->" in str(bug)                  # human-readable trace


def test_interleave_trace_replays_deterministically():
    """Re-applying the reproducer trace on a fresh harness hits the
    same violation -- it is a reproducer, not a flake."""
    from repro.analysis import interleave

    with pytest.raises(interleave.InterleavingBug) as ei:
        interleave.explore(
            lambda: interleave.LifecycleHarness(
                pool_cls=interleave.RefcountBlindPool),
            depth=4)
    trace = ei.value.trace
    h = interleave.LifecycleHarness(
        pool_cls=interleave.RefcountBlindPool)
    with pytest.raises(AssertionError):      # InvariantError family
        for op in trace:
            h.apply(op)
            h.verify()


def test_interleave_hypothesis_random_walks():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.analysis import interleave

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=63), max_size=12))
    def walk(indices):
        h = interleave.LifecycleHarness()
        h.apply_indices(indices)             # verifies after every op

    walk()


# ----------------------------------------------------------------------
# determinism satellite: fleet report invariant under PYTHONHASHSEED
# ----------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_report_byte_identical_across_hash_seeds():
    """Same seed => byte-identical serialized report even when set/dict
    hash order differs (the R005 fixes in ``fleet/sim.py``)."""
    script = textwrap.dedent("""
        import json
        from repro.fleet import (FleetSim, NodeSpec, PreemptionPolicy,
                                 poisson_trace)
        from repro.fleet.workload import LengthDist

        fleet = [NodeSpec("a100-40g", 1, "prefill"),
                 NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                          kv_pool_pages=40, page_size=16),
                 NodeSpec("cmp-170hx-nofma", 1, "decode", decode_lanes=8,
                          kv_pool_pages=512, page_size=16)]
        trace = poisson_trace(3.0, 40.0, seed=2,
                              prompt=LengthDist(256, cv=0.3),
                              gen=LengthDist(128, cv=0.5))
        rep = FleetSim(fleet, trace, fmt="q8_0",
                       preemption=PreemptionPolicy()).run()
        print(json.dumps({"metrics": rep.metrics(),
                          "preempts": [str(e) for e in rep.preempt_events]},
                         sort_keys=True, default=str))
    """)
    outs = []
    for seed in ("1", "2"):
        r = subprocess.run(
            [sys.executable, "-c", script], capture_output=True,
            text=True, env=_src_env(PYTHONHASHSEED=seed))
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert '"preempts": ["' in outs[0]       # churn actually happened
