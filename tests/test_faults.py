"""Fault-tolerant serving: injection, recovery, retry/hedging, shedding.

Four layers of invariants around the contract "a crash costs time,
never tokens":

* plan/policy -- :class:`FaultPlan` is seeded, validated and immutable;
  :class:`RetryPolicy` backs off with a cap and honors deadlines;
  :class:`~repro.train.fault_tolerance.StragglerMonitor` flags a derated
  host on an injected clock (and why that needs >= 3 hosts);
* simulator -- a mid-trace crash with a :class:`RecoveryPolicy` loses
  nothing (checkpointed lanes migrate, the rest replay), without one the
  crash visibly loses requests; faulted runs are bit-deterministic and
  their counters land in the ``fleet.faults.*`` registry namespace;
* engine -- :func:`validate_recovery_exactness` pins that lanes resumed
  from checkpoints AND lanes replayed from the prompt reproduce the
  undisturbed greedy streams token for token (hypothesis drives random
  crash/checkpoint/transient interleavings through the same oracle);
* degradation -- the engine ladder escalates shed-batch -> backpressure
  -> evict in order, de-escalates on cooldown, and never changes the
  token streams; admission failures surface as structured
  :class:`AdmissionRejected` (with the legacy ``RuntimeError`` contract
  intact; the old ``AdmissionError`` alias is gone).
"""

import dataclasses

import numpy as np
import pytest

from repro.fleet import (FaultEvent, FaultInjector, FaultPlan, FleetSim,
                         LengthDist, NodeSpec, RecoveryPolicy, RetryPolicy,
                         poisson_trace)
from repro.serving.resilience import (DEGRADE_LEVELS, AdmissionRejected,
                                      DegradationLadder)
from repro.train.fault_tolerance import StragglerMonitor

pytestmark = pytest.mark.faults


# ----------------------------------------------------------------------
# plan / policy units (no jax, no sim)
# ----------------------------------------------------------------------

class TestFaultPlan:
    def test_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent("meltdown", at_s=1.0)
        with pytest.raises(ValueError, match="exactly one"):
            FaultEvent("crash", at_s=1.0, at_dispatch=3)
        with pytest.raises(ValueError, match="exactly one"):
            FaultEvent("crash")
        with pytest.raises(ValueError, match="factor"):
            FaultEvent("derate", at_s=1.0, factor=0.5)
        with pytest.raises(ValueError, match="duration_s"):
            FaultEvent("transient", at_s=1.0)
        # dispatch-indexed transients carry no duration: that is legal
        FaultEvent("transient", at_dispatch=4)

    def test_seeded_deterministic(self):
        a = FaultPlan.seeded(3, n_nodes=4, horizon_s=60.0)
        b = FaultPlan.seeded(3, n_nodes=4, horizon_s=60.0)
        c = FaultPlan.seeded(4, n_nodes=4, horizon_s=60.0)
        assert a == b
        assert a != c
        kinds = [e.kind for e in a.events]
        for k in ("crash", "derate", "link", "transient"):
            assert k in kinds
        # crashes land mid-trace by construction
        for e in a.events:
            if e.kind == "crash":
                assert 0.25 * 60 <= e.at_s <= 0.75 * 60

    def test_merge_and_views(self):
        plan = (FaultPlan(events=(
            FaultEvent("crash", node=1, at_dispatch=6),
            FaultEvent("transient", at_dispatch=2),
            FaultEvent("transient", at_dispatch=9),
        )) + FaultPlan.flap("n0", t0=2.0, period_s=1.0, n_flaps=2))
        assert plan.crash_dispatch() == 6
        assert plan.transient_dispatches() == [2, 9]
        sim_evs = plan.sim_events()
        assert [e.at_s for e in sim_evs] == [2.0, 3.0]
        assert all(e.kind == "link" for e in sim_evs)
        with pytest.raises(dataclasses.FrozenInstanceError):
            plan.events = ()

    def test_injector_resolution(self):
        @dataclasses.dataclass
        class N:
            node_id: str
            failed: bool = False

        nodes = [N("b"), N("a"), N("c", failed=True)]
        inj = FaultInjector(FaultPlan())
        # ints index the ALIVE set sorted by node_id, modulo its size
        assert inj.resolve(FaultEvent("crash", node=0, at_s=1.0),
                           nodes).node_id == "a"
        assert inj.resolve(FaultEvent("crash", node=3, at_s=1.0),
                           nodes).node_id == "b"
        assert inj.resolve(FaultEvent("crash", node="b", at_s=1.0),
                           nodes).node_id == "b"
        assert inj.resolve(FaultEvent("crash", node="c", at_s=1.0),
                           nodes) is None          # failed: not a target
        assert inj.resolve(FaultEvent("crash", node="zz", at_s=1.0),
                           nodes) is None


class TestRetryPolicy:
    def test_backoff_caps(self):
        pol = RetryPolicy(max_attempts=5, base_backoff_s=0.1,
                          backoff_cap_s=0.5)
        assert pol.backoff_s(1) == pytest.approx(0.1)
        assert pol.backoff_s(2) == pytest.approx(0.2)
        assert pol.backoff_s(3) == pytest.approx(0.4)
        assert pol.backoff_s(4) == pytest.approx(0.5)   # capped
        assert pol.backoff_s(10) == pytest.approx(0.5)

    def test_allows(self):
        pol = RetryPolicy(max_attempts=2, deadline_s=1.0)
        assert pol.allows(1, waited_s=0.0)
        assert pol.allows(2, waited_s=0.99)
        assert not pol.allows(3, waited_s=0.0)      # attempts exhausted
        assert not pol.allows(1, waited_s=1.0)      # deadline blown


class TestStragglerMonitor:
    def test_injected_clock_begin_end(self):
        t = [0.0]
        mon = StragglerMonitor(n_hosts=1, warmup=1, clock=lambda: t[0])
        mon.begin(0)
        t[0] = 2.5
        assert mon.end(0) == pytest.approx(2.5)
        assert mon.ewma[0] == pytest.approx(2.5)

    def test_three_hosts_flag_two_cannot(self):
        # with two hosts the median IS their mean: a host derated by 3x
        # converges to exactly threshold x median and never crosses it.
        # A third healthy host pins the median and detection works --
        # the reason the bench/sim scenarios run >= 3 decode boards.
        def feed(n_hosts, slow_host, rounds=12):
            mon = StragglerMonitor(n_hosts=n_hosts, warmup=3)
            for _ in range(rounds):
                for h in range(n_hosts):
                    mon.record(h, 0.3 if h == slow_host else 0.1)
            return mon.stragglers()

        assert feed(2, slow_host=1) == []
        assert feed(3, slow_host=1) == [1]

    def test_reset_forgets_history(self):
        mon = StragglerMonitor(n_hosts=3, warmup=2)
        for _ in range(4):
            mon.record(0, 0.1)
            mon.record(1, 0.1)
            mon.record(2, 0.9)
        assert mon.stragglers() == [2]
        mon.reset(2)            # crashed host: stale EWMA must not flag
        assert mon.stragglers() == []
        assert mon.count[2] == 0


class TestDegradationLadder:
    def test_escalation_order_and_knobs(self):
        ladder = DegradationLadder(page_pressure=0.9, trip_after=2,
                                   cooldown=3)
        assert ladder.level_name == "normal"
        assert ladder.dispatch_n(8) == 8
        path = []
        for _ in range(6):
            ladder.note_pressure(0.95)
            path.append(ladder.level)
        assert path == [0, 1, 1, 2, 2, 3]       # one rung per trip_after
        assert ladder.level_name == "evict"
        assert ladder.dispatch_n(8) == 1        # 8 >> 3
        assert ladder.refusing_admissions and ladder.should_evict
        assert ladder.retry_after_s(0.05) == pytest.approx(0.2)
        # strikes do not escalate past the top rung
        ladder.note_pressure(0.95)
        ladder.note_pressure(0.95)
        assert ladder.level == 3

    def test_cooldown_deescalates_one_rung(self):
        ladder = DegradationLadder(trip_after=1, cooldown=2)
        ladder.note_admission_blocked(uid=7)
        ladder.note_admission_blocked(uid=7)
        assert ladder.level == 2
        ladder.note_ok()
        assert ladder.level == 2                # cooldown not met yet
        ladder.note_ok()
        assert ladder.level == 1
        # a strike resets the clear streak
        ladder.note_ok()
        ladder.note_pressure(0.99)
        ladder.note_ok()
        assert ladder.level == 2

    def test_transitions_logged_and_emitted(self):
        from repro.obs.events import DEFAULT_LOG
        before = len(DEFAULT_LOG.records("degrade.transition"))
        ladder = DegradationLadder(trip_after=1, cooldown=1,
                                   name="ladder-under-test")
        ladder.note_pressure(1.0)
        ladder.note_ok()
        assert [(a, b) for a, b, _ in ladder.transitions] == [(0, 1), (1, 0)]
        evs = [e for e in DEFAULT_LOG.records("degrade.transition")
               if e.fields.get("engine") == "ladder-under-test"]
        assert len(DEFAULT_LOG.records("degrade.transition")) == before + 2
        assert [e.fields["to_level"] for e in evs] == ["shed_batch",
                                                       "normal"]
        assert all(e.fields["from_level"] in DEGRADE_LEVELS for e in evs)


class TestAdmissionRejected:
    def test_structured_fields_and_legacy_phrase(self):
        err = AdmissionRejected(uid=9, reason="never_admissible",
                                need_pages=12, pool_pages=8, n_lanes=2)
        assert isinstance(err, RuntimeError)
        assert "can never be admitted" in str(err)
        assert (err.uid, err.reason) == (9, "never_admissible")
        assert err.retry_after_s is None
        back = AdmissionRejected(uid=3, reason="backpressure",
                                 retry_after_s=0.2)
        assert back.retry_after_s == pytest.approx(0.2)
        assert "backpressure" in str(back)

    def test_legacy_alias_removed(self):
        import repro.serving.engine as engine_mod
        with pytest.raises(AttributeError):
            engine_mod.AdmissionError


# ----------------------------------------------------------------------
# simulator: crash recovery, derate detection, retry/hedging
# ----------------------------------------------------------------------

def _specs(n_decode=2, decode_lanes=4):
    return [NodeSpec("a100-40g", 1, "prefill"),
            NodeSpec("cmp-170hx-nofma", n_decode, "decode",
                     decode_lanes=decode_lanes, kv_pool_pages=256,
                     page_size=16)]


def _trace(rate=4.0, dur=20.0, seed=0):
    return poisson_trace(rate, dur, seed=seed,
                         prompt=LengthDist(128, cv=0.3),
                         gen=LengthDist(256, cv=0.5))


CRASH_PLAN = FaultPlan(events=(
    FaultEvent("crash", node="cmp-170hx-nofma/decode#1", at_s=8.0),))
# tick well below the per-request decode time, so lanes live at the
# crash have a checkpoint to resume from
RECOVERY = RecoveryPolicy(checkpoint_interval_s=0.1,
                          retry=RetryPolicy(max_attempts=4))


class TestSimCrashRecovery:
    def test_recovery_loses_nothing(self):
        rep = FleetSim(_specs(), _trace(), faults=CRASH_PLAN,
                       recovery=RECOVERY).run()
        assert rep.crashes == 1
        assert rep.recovered_lanes >= 1
        assert rep.requests_lost == 0
        assert rep.completed == rep.offered
        assert rep.checkpoints > 0
        assert any("CRASH" in line for line in rep.fault_events)
        assert any("RECOVER" in line for line in rep.fault_events)

    def test_no_recovery_loses_inflight_work(self):
        rep = FleetSim(_specs(), _trace(), faults=CRASH_PLAN).run()
        assert rep.crashes == 1
        assert rep.recovered_lanes == 0
        assert rep.requests_lost > 0
        assert rep.completed + rep.requests_lost <= rep.offered

    def test_faulted_run_is_deterministic(self):
        mk = lambda: FleetSim(_specs(), _trace(), faults=CRASH_PLAN,
                              recovery=RECOVERY).run()
        assert mk() == mk()

    def test_counters_land_in_registry(self):
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry()
        rep = FleetSim(_specs(), _trace(), faults=CRASH_PLAN,
                       recovery=RECOVERY, registry=registry).run()
        vals = registry.collect()
        assert vals["fleet.faults.crashes"] == rep.crashes == 1
        assert vals["fleet.faults.requests_lost"] == 0
        assert vals["fleet.retry.attempts"] == rep.retries

    def test_retry_exhaustion_marks_lost(self):
        # the ONLY decode board dies: every in-flight and queued request
        # retries with backoff until the policy gives up, then is LOST
        plan = FaultPlan(events=(
            FaultEvent("crash", node="cmp-170hx-nofma/decode#1",
                       at_s=5.0),))
        rep = FleetSim(_specs(n_decode=1), _trace(dur=15.0), faults=plan,
                       recovery=RecoveryPolicy(
                           checkpoint_interval_s=0.5,
                           retry=RetryPolicy(max_attempts=2))).run()
        assert rep.crashes == 1
        assert rep.retries > 0
        assert rep.requests_lost > 0
        assert any("LOST" in line for line in rep.fault_events)


class TestSimDerateAndLink:
    def test_derate_dilates_decode_and_is_detected(self):
        # 3 decode boards so the monitor's median is pinned by healthy
        # hosts (see TestStragglerMonitor.test_three_hosts_flag_two_cannot)
        specs = _specs(n_decode=3)
        trace = _trace(rate=6.0, dur=20.0, seed=2)
        plan = FaultPlan(events=(
            FaultEvent("derate", node="cmp-170hx-nofma/decode#1",
                       at_s=3.0, factor=3.0, duration_s=10.0),))
        base = FleetSim(specs, trace).run()
        rep = FleetSim(specs, trace, faults=plan,
                       recovery=RECOVERY).run()
        assert rep.derates == 1
        assert rep.tpot_p99_s > base.tpot_p99_s
        assert any("decode#1" in line for line in rep.derate_detected)
        flagged = {line.split("STRAGGLER ")[1].split(" ")[0]
                   for line in rep.derate_detected}
        assert flagged == {"cmp-170hx-nofma/decode#1"}
        # the derate window CLEARs and the sim still completes everything
        assert any("CLEAR" in line for line in rep.fault_events)
        assert rep.completed == rep.offered

    def test_link_flap_counts_windows(self):
        plan = FaultPlan.flap("a100-40g/prefill#0", t0=2.0, period_s=2.0,
                              n_flaps=3, factor=4.0)
        base = FleetSim(_specs(), _trace()).run()
        rep = FleetSim(_specs(), _trace(), faults=plan,
                       recovery=RECOVERY).run()
        assert rep.link_faults == 3
        assert rep.completed == rep.offered
        assert rep.ttft_p99_s >= base.ttft_p99_s

    def test_transient_stalls_node(self):
        plan = FaultPlan(events=(
            FaultEvent("transient", node="cmp-170hx-nofma/decode#1",
                       at_s=4.0, duration_s=1.0),))
        base = FleetSim(_specs(), _trace()).run()
        rep = FleetSim(_specs(), _trace(), faults=plan,
                       recovery=RECOVERY).run()
        assert rep.transients == 1
        assert rep.completed == rep.offered
        assert rep.tpot_p99_s >= base.tpot_p99_s


class TestSimHedging:
    def test_hedge_fires_for_long_queued_requests(self):
        # saturate ONE prefill board so arrivals queue well past the
        # hedge trigger; duplicates launch on the second board and the
        # first copy to start wins -- nothing is served twice
        specs = [NodeSpec("a100-40g", 2, "prefill"),
                 NodeSpec("cmp-170hx-nofma", 2, "decode",
                          decode_lanes=4, kv_pool_pages=256,
                          page_size=16)]
        trace = poisson_trace(40.0, 5.0, seed=1,
                              prompt=LengthDist(1024, cv=0.3),
                              gen=LengthDist(64, cv=0.4))
        rec_pol = RecoveryPolicy(
            checkpoint_interval_s=1.0,
            retry=RetryPolicy(max_attempts=3, hedge_after_s=0.2))
        rep = FleetSim(specs, trace, faults=FaultPlan(),
                       recovery=rec_pol).run()
        assert rep.hedges > 0
        assert rep.completed == rep.offered
        assert rep.requests_lost == 0


# ----------------------------------------------------------------------
# engine: crash-recovery exactness, degradation ladder (jax)
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


ORACLE_TRACE_KW = dict(seed=3, prompt=LengthDist(12, cv=0.3),
                       gen=LengthDist(14, cv=0.4))
ORACLE_KW = dict(n_lanes=2, max_len=32, dispatch_n=4, page_size=8, seed=5)


class TestRecoveryExactness:
    def test_oracle_exercises_both_paths(self, small_model):
        from repro.fleet import validate_recovery_exactness

        cfg, params = small_model
        trace = poisson_trace(2.0, 6.0, **ORACLE_TRACE_KW)
        # crash at dispatch 10: on this trace one live lane has a
        # checkpoint (resumes) and one does not (replays from prompt)
        verdict = validate_recovery_exactness(
            trace, cfg, params, crash_at_dispatch=10, checkpoint_every=3,
            transient_dispatches=(2,), **ORACLE_KW)
        assert verdict["resume_exact"], verdict["mismatches"]
        assert verdict["replay_exact"], verdict["mismatches"]
        assert verdict["counts_match"]
        assert verdict["crashes"] == 1
        assert verdict["recovered_lanes"] >= 1
        assert verdict["replayed_from_prompt"] >= 1
        assert verdict["retry_attempts"] > 0
        assert verdict["checkpoints"] > 0

    def test_replay_counts_retries_in_engine_stats(self, small_model):
        from repro.fleet import run_trace_with_faults
        from repro.fleet.workload import FleetRequest

        cfg, params = small_model
        trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=6 + i,
                              gen_len=8) for i in range(4)]
        out = run_trace_with_faults(trace, cfg, params,
                                    crash_at_dispatch=4,
                                    checkpoint_every=2,
                                    transient_dispatches=(1,),
                                    **ORACLE_KW)
        # transient retry + one recovery admission per casualty, carried
        # into the SURVIVING engine's counter (node0's died with it)
        assert out.crashes == 1
        assert out.transients == 1
        assert out.retry_attempts >= 1 + len(out.checkpointed_uids
                                             + out.replayed_uids)

    def test_plan_drives_replay(self, small_model):
        from repro.fleet import run_trace_with_faults
        from repro.fleet.workload import FleetRequest

        cfg, params = small_model
        trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=6 + i,
                              gen_len=8) for i in range(4)]
        plan = FaultPlan(events=(
            FaultEvent("transient", at_dispatch=1),
            FaultEvent("crash", at_dispatch=4),))
        via_plan = run_trace_with_faults(trace, cfg, params, plan=plan,
                                         checkpoint_every=2, **ORACLE_KW)
        via_knobs = run_trace_with_faults(trace, cfg, params,
                                          crash_at_dispatch=4,
                                          checkpoint_every=2,
                                          transient_dispatches=(1,),
                                          **ORACLE_KW)
        assert via_plan == via_knobs


class TestEngineLadder:
    def test_ladder_sheds_without_changing_tokens(self, small_model):
        from repro.serving import Request, ServeEngine

        cfg, params = small_model
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
                   for _ in range(6)]

        def reqs():
            return [Request(uid=i, prompt=prompts[i].copy(),
                            max_new_tokens=12, priority=i % 2)
                    for i in range(6)]
        kw = dict(n_lanes=4, max_len=32, dispatch_n=4, paged=True,
                  page_size=8, n_pages=10)
        plain = ServeEngine(cfg, params, **kw)
        plain.run(reqs())
        ladder = DegradationLadder(page_pressure=0.5, trip_after=1,
                                   cooldown=50)
        eng = ServeEngine(cfg, params, ladder=ladder, **kw)
        served = eng.run(reqs())
        # the ladder escalated under the tight pool and shed at least
        # one lane to a checkpoint -- yet every stream is untouched
        assert eng.stats["degrade_transitions"] > 0
        assert eng.stats["degrade_sheds"] > 0
        assert ladder.level_name in DEGRADE_LEVELS
        base = ServeEngine(cfg, params, **kw)
        base_reqs = reqs()
        base.run(base_reqs)
        assert ([list(r.generated) for r in served]
                == [list(r.generated) for r in base_reqs])
        eng.pool.check()
        assert eng.pool.n_in_use == 0

    def test_never_admissible_is_structured(self, small_model):
        from repro.serving import Request, ServeEngine

        cfg, params = small_model
        # zero lanes: nothing can ever be admitted and nothing is in
        # flight to retire (the pinned legacy livelock case)
        eng = ServeEngine(cfg, params, n_lanes=0, max_len=32,
                          dispatch_n=4)
        req = Request(uid=7,
                      prompt=np.arange(5, dtype=np.int32) % 7,
                      max_new_tokens=4)
        with pytest.raises(RuntimeError, match="never be admitted") as ei:
            eng.run([req])
        assert isinstance(ei.value, AdmissionRejected)
        assert ei.value.reason == "never_admissible"
        assert ei.value.uid == 7
        assert ei.value.retry_after_s is None
        assert ei.value.n_lanes == 0
        assert eng.stats["admit_rejected"] == 1


# ----------------------------------------------------------------------
# churn properties: random crash/checkpoint/transient interleavings
# ----------------------------------------------------------------------

def _churn_trace():
    from repro.fleet.workload import FleetRequest
    return [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=5 + i,
                         gen_len=8) for i in range(5)]


def _assert_churn_invariant(small_model, base, crash_at, checkpoint_every,
                            transients):
    """Whatever the evict/restore/crash/retry interleaving, the paged
    pool balances (asserted inside the replay) and every request's
    greedy stream is bit-identical to the undisturbed run."""
    from repro.fleet import run_trace_with_faults

    cfg, params = small_model
    out = run_trace_with_faults(_churn_trace(), cfg, params,
                                crash_at_dispatch=crash_at,
                                checkpoint_every=checkpoint_every,
                                transient_dispatches=transients,
                                **ORACLE_KW)
    assert out.streams == base.streams, (crash_at, checkpoint_every,
                                         transients)
    if crash_at is not None:
        assert out.crashes <= 1


class TestChurnProperties:
    @pytest.fixture(scope="class")
    def base(self, small_model):
        from repro.fleet import run_trace_with_faults
        cfg, params = small_model
        return run_trace_with_faults(_churn_trace(), cfg, params,
                                     **ORACLE_KW)

    def test_seeded_random_interleavings(self, small_model, base):
        # deterministic fallback for containers without hypothesis:
        # the same invariant over a seeded sample of interleavings
        rng = np.random.default_rng(11)
        for _ in range(6):
            crash_at = (int(rng.integers(1, 13))
                        if rng.random() < 0.8 else None)
            checkpoint_every = int(rng.integers(1, 6))
            transients = sorted(set(
                rng.integers(0, 11, rng.integers(0, 4)).tolist()))
            _assert_churn_invariant(small_model, base, crash_at,
                                    checkpoint_every, transients)

    def test_streams_survive_any_interleaving(self, small_model, base):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=8, deadline=None)
        @given(crash_at=st.one_of(st.none(), st.integers(1, 12)),
               checkpoint_every=st.integers(1, 5),
               transients=st.lists(st.integers(0, 10), max_size=3,
                                   unique=True))
        def run(crash_at, checkpoint_every, transients):
            _assert_churn_invariant(small_model, base, crash_at,
                                    checkpoint_every, sorted(transients))

        run()
