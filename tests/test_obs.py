"""Telemetry layer: metrics registry, span tracer, event log, and the
sim-to-real calibration gate.

Four layers of invariants:

* instruments -- counter/gauge/histogram semantics, get-or-create with
  kind checking, exact percentiles, JSON snapshot, Prometheus text
  exposition, and the :class:`~repro.obs.StatsView` legacy-dict facade
  every engine's ``stats`` now is;
* tracer -- per-track nesting is enforced and well-nested, disabled
  tracers record nothing, Chrome-trace export round-trips
  ``json.loads`` with the Perfetto-loadable schema, and span durations
  feed the registry's ``span.*.seconds`` histograms;
* engine -- tracing is exactness-neutral (identical token streams AND
  identical compile counters traced vs untraced: spans wrap host work
  only, nothing enters a jitted computation), one ``decode.dispatch``
  span per counted dispatch, page-pool occupancy readable through
  callback gauges, validators emit verdict events;
* calibration -- :func:`~repro.obs.predict_replay` mirrors the real
  engine's scheduling exactly on a measured replay, and a deliberately
  perturbed phase model FAILS the drift gate (the gate's self-test).
"""

import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.obs import (DEFAULT_LOG, EventLog, MetricsRegistry, SpanTracer,
                       StatsView, calibrate_replay, fit_dispatch_time_model,
                       fit_linear, predict_replay, rel_err)
from repro.obs.trace import Span
from repro.serving import Request, ServeEngine

pytestmark = pytest.mark.obs


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
            for n in lens]


def _reqs(prompts, max_new):
    return [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


ENGINE_KW = dict(n_lanes=2, max_len=64, dispatch_n=4, paged=True,
                 page_size=8, n_pages=10)


# ----------------------------------------------------------------------
# instruments
# ----------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("x.events", help="events")
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.set(0)                                    # bench-reset path
    assert c.value == 0

    g = reg.gauge("x.level")
    g.set(3)
    g.set_max(2)
    assert g.value == 3
    g.set_max(7)
    assert g.value == 7

    backing = {"v": 11}
    live = reg.gauge("x.live", fn=lambda: backing["v"])
    assert live.value == 11
    backing["v"] = 13
    assert live.value == 13                     # read-through, no publish
    with pytest.raises(AssertionError):
        live.set(1)                             # callback gauges are RO

    h = reg.histogram("x.lat")
    assert math.isnan(h.percentile(50))
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.percentile(50) == pytest.approx(2.5)   # exact, interpolated
    assert h.percentile(0) == 1.0 and h.percentile(100) == 4.0
    assert h.summary() == {"count": 4, "sum": 10.0,
                           "p50": h.percentile(50), "p99": h.percentile(99)}


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("serve.decode.dispatches")
    assert reg.counter("serve.decode.dispatches") is a
    with pytest.raises(AssertionError):
        reg.gauge("serve.decode.dispatches")    # kind is part of the schema
    a.inc(2)
    reg.histogram("span.x.seconds").observe(0.5)
    snap = reg.collect()
    assert snap["serve.decode.dispatches"] == 2
    assert snap["span.x.seconds"]["count"] == 1
    assert "serve.decode.dispatches" in reg
    json.dumps(snap)                            # JSON-friendly by contract


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("serve.decode.dispatches", help="jitted decode blocks").inc(3)
    reg.gauge("pool.pages.in_use").set(5)
    h = reg.histogram("span.decode.dispatch.seconds")
    h.observe(0.25)
    text = reg.to_prometheus()
    assert "# TYPE serve_decode_dispatches counter" in text
    assert "serve_decode_dispatches 3" in text
    assert "# HELP serve_decode_dispatches jitted decode blocks" in text
    assert "pool_pages_in_use 5" in text
    assert 'span_decode_dispatch_seconds{quantile="0.5"} 0.25' in text
    assert "span_decode_dispatch_seconds_count 1" in text
    assert text.endswith("\n")


def test_statsview_legacy_dict_compat():
    reg = MetricsRegistry()
    keymap = {"decode_dispatches": "serve.decode.dispatches",
              "generated_tokens": "serve.tokens.generated"}
    for name in keymap.values():
        reg.counter(name)
    stats = StatsView(reg, keymap)
    stats["decode_dispatches"] += 1             # the hot-path idiom
    stats["generated_tokens"] += 8
    assert dict(stats) == {"decode_dispatches": 1, "generated_tokens": 8}
    assert stats == {"decode_dispatches": 1, "generated_tokens": 8}
    assert stats != {"decode_dispatches": 2, "generated_tokens": 8}
    assert sorted(k for k, _ in stats.items()) == sorted(keymap)
    # writes land in the registry, not a shadow dict
    assert reg["serve.tokens.generated"].value == 8
    # bench reset idiom
    for k in stats:
        stats[k] = 0
    assert all(v == 0 for v in stats.values())
    with pytest.raises(KeyError):
        stats["invented_key"] = 1               # schema is authoritative
    with pytest.raises(TypeError):
        del stats["decode_dispatches"]


# ----------------------------------------------------------------------
# tracer
# ----------------------------------------------------------------------

def test_tracer_nesting_and_queries():
    t = [0.0]
    tr = SpanTracer(clock=lambda: t[0])
    with tr.span("outer", track="lane0", uid=1):
        t[0] = 1.0
        with tr.span("inner", track="lane0"):
            t[0] = 2.0
        t[0] = 3.0
    tr.add_span("sim.decode", 0.5, 4.5, track="node0", uid=2)
    tr.instant("retire", track="lane0", uid=1)
    assert [s.name for s in tr.spans] == ["inner", "outer", "sim.decode"]
    assert tr.spans_named("outer")[0].duration_s == 3.0
    assert tr.spans_named("outer")[0].args == {"uid": 1}
    assert sorted(tr.tracks()) == ["lane0", "node0"]
    assert tr.check_well_nested()
    # partial overlap on one track is NOT well-nested
    bad = SpanTracer()
    bad.add_span("a", 0.0, 2.0, track="x")
    bad.add_span("b", 1.0, 3.0, track="x")
    assert not bad.check_well_nested()


def test_disabled_tracer_records_nothing():
    reg = MetricsRegistry()
    tr = SpanTracer(enabled=False, registry=reg)
    with tr.span("decode.dispatch", track="serve"):
        pass
    assert tr.instant("retire") is None
    assert tr.add_span("x", 0.0, 1.0) is None
    assert tr.spans == [] and tr.instants == []
    assert reg.names() == []                    # no histogram feed either


def test_chrome_trace_round_trips_json():
    t = [0.0]
    tr = SpanTracer(clock=lambda: t[0])
    with tr.span("admit", track="serve/lane0", uid=3):
        t[0] = 0.001
        with tr.span("prefill.bucket", track="serve/lane0", bucket=8):
            t[0] = 0.002
    tr.instant("retire", track="serve/lane0", uid=3)
    obj = json.loads(tr.to_json())              # round-trip by contract
    assert obj["displayTimeUnit"] == "ms"
    evs = obj["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert {e["args"]["name"] for e in meta} == {"serve/lane0"}
    assert len(spans) == 2 and len(instants) == 1
    admit = next(e for e in spans if e["name"] == "admit")
    assert admit["ts"] == 0.0                   # relative microseconds
    assert admit["dur"] == pytest.approx(2000.0)
    assert admit["args"] == {"uid": 3}
    assert all(e["tid"] == meta[0]["tid"] for e in spans + instants)


def test_span_durations_feed_registry_histograms():
    reg = MetricsRegistry()
    tr = SpanTracer(registry=reg)
    tr.add_span("decode.dispatch", 0.0, 0.5, track="serve")
    tr.add_span("decode.dispatch", 0.0, 1.5, track="serve")
    h = reg["span.decode.dispatch.seconds"]
    assert h.count == 2 and h.sum == pytest.approx(2.0)


# ----------------------------------------------------------------------
# event log
# ----------------------------------------------------------------------

def test_event_log_and_module_emit():
    from repro.obs import events
    log = EventLog(clock=lambda: 42.0)
    log.emit("validate.x", ok=True, n=3)
    log.emit("other", ok=False)
    assert len(log) == 2
    (ev,) = log.records("validate.x")
    assert ev.fields == {"ok": True, "n": 3} and ev.t == 42.0
    d = json.loads(log.to_json())
    assert [e["name"] for e in d] == ["validate.x", "other"]
    log.clear()
    assert len(log) == 0

    n0 = len(DEFAULT_LOG)
    events.emit("test.ping", tag="obs")
    assert len(DEFAULT_LOG) == n0 + 1
    assert DEFAULT_LOG.records("test.ping")[-1].fields == {"tag": "obs"}


# ----------------------------------------------------------------------
# calibration (host-side)
# ----------------------------------------------------------------------

def test_fit_linear_recovers_constants():
    a, b = fit_linear([1, 2, 4, 8], [0.3 + 0.05 * x for x in (1, 2, 4, 8)])
    assert a == pytest.approx(0.3) and b == pytest.approx(0.05)
    a, b = fit_linear([4, 4, 4], [1.0, 2.0, 3.0])   # degenerate x
    assert a == pytest.approx(2.0) and b == 0.0


def test_fit_dispatch_time_model_from_spans():
    spans = [Span("decode.dispatch", "serve", 0.0, 0.1 + 0.02 * n,
                  args={"n_steps": n, "n_live": 1})
             for n in (1, 2, 4, 8)]
    spans.append(Span("admit", "serve/lane0", 0.0, 9.0))  # ignored
    fit = fit_dispatch_time_model(spans)
    assert fit["n_spans"] == 4
    assert fit["t_dispatch_overhead_s"] == pytest.approx(0.1)
    assert fit["t_per_step_s"] == pytest.approx(0.02)
    assert fit_dispatch_time_model([]) == {}


def test_predict_replay_hand_checkable():
    class R:
        def __init__(self, uid, plen, gen):
            self.uid, self.arrival_s = uid, 0.0
            self.prompt_len, self.gen_len = plen, gen

    # one request, gen=5, dispatch_n=8: one dispatch of a pow2-shrunk
    # 8-step block, 5 tokens out
    p = predict_replay([R(0, 4, 5)], n_lanes=2, max_len=64)
    assert (p.decode_dispatches, p.decode_steps, p.generated_tokens) \
        == (1, 8, 5)
    # paged: worst case ceil((4+5+1)/8)=2 pages reserved at admit
    p = predict_replay([R(0, 4, 5)], n_lanes=2, max_len=64, paged=True,
                       page_size=8)
    assert p.kv_pages_hwm == 2 and p.kv_admit_blocked == 0


def test_calibration_report_gate():
    class Real:
        decode_dispatches, decode_steps = 10, 40
        gen_tokens, kv_pages_hwm = 35, 6

    class Sim:
        def as_dict(self):
            return {"decode_dispatches": 10, "decode_steps": 40,
                    "generated_tokens": 35, "kv_pages_hwm": 6,
                    "kv_admit_blocked": 0}

    rep = calibrate_replay(Real(), Sim())
    assert rep.ok and rep.max_rel_err == 0.0
    assert set(rep.metrics) == {"decode_dispatches", "decode_steps",
                                "generated_tokens", "kv_pages_hwm"}
    json.dumps(rep.as_dict())

    class Drifted(Sim):
        def as_dict(self):
            return dict(Sim.as_dict(self), kv_pages_hwm=9)

    bad = calibrate_replay(Real(), Drifted())
    assert not bad.ok
    assert bad.metrics["kv_pages_hwm"]["rel_err"] == pytest.approx(0.5)
    assert rel_err(0.0, 0.0) == 0.0             # counter-friendly at zero


# ----------------------------------------------------------------------
# engine integration
# ----------------------------------------------------------------------

def test_tracing_is_exactness_neutral(small_model):
    """Overhead budget: tracing on vs off -- identical token streams and
    identical compile counters (spans never enter jitted code)."""
    cfg, params = small_model
    prompts = _prompts(cfg, [5, 9, 6, 12])

    def serve(traced):
        reg = MetricsRegistry()
        eng = ServeEngine(cfg, params, tracer=SpanTracer(enabled=traced,
                                                         registry=reg),
                          registry=reg, **ENGINE_KW)
        reqs = _reqs(prompts, max_new=10)
        eng.run(reqs)
        return [tuple(r.generated) for r in reqs], dict(eng.stats)

    out_off, stats_off = serve(False)
    out_on, stats_on = serve(True)
    assert out_on == out_off
    for k in ("prefill_compiles", "ssm_prefill_compiles",
              "decode_compiles"):
        assert stats_on[k] == stats_off[k], k


def test_dispatch_spans_match_counters(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, [5, 9, 6, 12])
    reg = MetricsRegistry()
    tr = SpanTracer(registry=reg)
    eng = ServeEngine(cfg, params, tracer=tr, registry=reg, **ENGINE_KW)
    reqs = _reqs(prompts, max_new=10)
    eng.run(reqs)

    assert tr.check_well_nested()
    assert len(tr.spans_named("decode.dispatch")) \
        == eng.stats["decode_dispatches"]
    assert len(tr.spans_named("admit")) == len(reqs)
    assert len([e for e in tr.instants if e.name == "retire"]) == len(reqs)
    # engine dispatches on its own track; lanes each get one
    assert eng.name in tr.tracks()
    assert any(t.startswith(f"{eng.name}/lane") for t in tr.tracks())
    # durations landed in the registry histograms behind the bench p50/p99
    assert reg["span.decode.dispatch.seconds"].count \
        == eng.stats["decode_dispatches"]
    # spans are monotone and closed
    assert all(s.t1 >= s.t0 for s in tr.spans)
    # export is Perfetto-loadable JSON
    obj = json.loads(tr.to_json())
    assert {e["name"] for e in obj["traceEvents"] if e["ph"] == "X"} \
        >= {"admit", "prefill.bucket", "decode.dispatch"}


def test_pagepool_registry_gauges(small_model):
    cfg, params = small_model
    reg = MetricsRegistry()
    eng = ServeEngine(cfg, params, registry=reg, name="serve", **ENGINE_KW)
    assert reg["serve.pool.pages.free"].value == eng.pool.n_free
    eng.run(_reqs(_prompts(cfg, [5, 9]), max_new=6))
    assert reg["serve.pool.pages.in_use"].value == 0     # all retired
    assert reg["serve.pool.pages.hwm"].value == eng.pool.hwm > 0
    assert reg["serve.pool.pages.allocs"].value \
        == reg["serve.pool.pages.frees"].value > 0
    # legacy flat keys still answer through the same registry
    assert eng.stats["kv_pages_hwm"] == eng.pool.hwm


def test_calibration_gate_on_real_replay(small_model):
    """predict_replay matches the measured replay exactly; perturbed
    phase models fail the same gate (self-test)."""
    from repro.fleet.execution import run_trace_on_engine
    from repro.fleet.workload import FleetRequest

    cfg, params = small_model
    trace = [FleetRequest(uid=i, arrival_s=0.05 * i,
                          prompt_len=3 + i % 4, gen_len=2 + i % 5)
             for i in range(6)]
    reg = MetricsRegistry()
    tr = SpanTracer(registry=reg)
    kw = dict(n_lanes=2, max_len=64, dispatch_n=4, paged=True, page_size=8)
    real = run_trace_on_engine(trace, cfg, params, tracer=tr,
                               registry=reg, **kw)
    sim = predict_replay(trace, **kw)
    rep = calibrate_replay(real, sim, spans=tr.spans)
    assert rep.ok and rep.max_rel_err == 0.0
    assert rep.fitted["n_spans"] == real.decode_dispatches

    pert = predict_replay(trace, **dict(kw, dispatch_n=1))
    assert not calibrate_replay(real, pert).ok
    pert = predict_replay(trace, **dict(kw, page_size=2))
    assert not calibrate_replay(real, pert).ok


def test_execution_result_spill_alias_removed(small_model):
    """kv_spill_events once aliased the engine's blocked-admission
    counter; the alias is gone (the simulator's counter of that name is
    a DIFFERENT event) -- only kv_admit_blocked remains."""
    from repro.fleet.execution import run_trace_on_engine
    from repro.fleet.workload import FleetRequest

    cfg, params = small_model
    trace = [FleetRequest(uid=i, arrival_s=0.0, prompt_len=4, gen_len=3)
             for i in range(3)]
    res = run_trace_on_engine(trace, cfg, params, n_lanes=2, max_len=64,
                              dispatch_n=4, paged=True, page_size=8)
    assert res.kv_admit_blocked >= 0
    assert not hasattr(res, "kv_spill_events")


def test_validators_emit_verdict_events(small_model):
    from repro.fleet.execution import validate_preemption_exactness
    from repro.fleet.workload import FleetRequest

    cfg, params = small_model
    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=4 + i,
                          gen_len=5) for i in range(3)]
    DEFAULT_LOG.clear()
    out = validate_preemption_exactness(trace, cfg, params,
                                        preempt_every=1, n_lanes=2,
                                        max_len=64, dispatch_n=4,
                                        page_size=8)
    (ev,) = DEFAULT_LOG.records("validate.preemption_exactness")
    assert ev.fields["resume_exact"] is True is out["resume_exact"]
    assert ev.fields["preemptions"] == out["preemptions"] > 0
    assert ev.fields["n_mismatches"] == 0


def test_multimodel_validator_emits_event(small_model):
    from repro.fleet.execution import validate_multimodel_exactness
    from repro.fleet.workload import FleetRequest

    cfg, params = small_model
    cfg_b = get_config("olmo-1b", smoke=True)
    params_b = build_model(cfg_b).init(jax.random.PRNGKey(1))
    models = {"a": (cfg, params), "b": (cfg_b, params_b)}
    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=4,
                          gen_len=4, model_id="a" if i % 2 == 0 else "b")
             for i in range(4)]
    DEFAULT_LOG.clear()
    out = validate_multimodel_exactness(trace, models, n_lanes=2,
                                        max_len=64, dispatch_n=4,
                                        page_size=8)
    (ev,) = DEFAULT_LOG.records("validate.multimodel_exactness")
    assert ev.fields["exact"] is True is out["exact"]
    assert ev.fields["model_swaps"] == out["model_swaps"]


def test_fleet_sim_spans_and_gauges():
    from repro.fleet import FleetSim, NodeSpec
    from repro.fleet.workload import LengthDist, poisson_trace

    trace = poisson_trace(10.0, 2.0, seed=3,
                          prompt=LengthDist(256, cv=0.3),
                          gen=LengthDist(64, cv=0.3))
    reg = MetricsRegistry()
    tr = SpanTracer(registry=reg)
    sim = FleetSim([NodeSpec("cmp-170hx-nofma", 2, "both", 4)], trace,
                   fmt="q8_0", tracer=tr, registry=reg)
    rep = sim.run()
    assert len(tr.spans_named("sim.prefill")) == rep.completed > 0
    assert len(tr.spans_named("sim.decode")) == rep.completed
    assert tr.check_well_nested()
    # sim-clock timestamps are simulated seconds, not host time
    assert max(s.t1 for s in tr.spans) <= rep.makespan_s + 1e-9
    # report gauges mirror FleetReport.metrics()
    assert reg["fleet.completed"].value == rep.completed
    # per-node callback gauges read through live node state
    node_gauges = [n for n in reg.names() if n.startswith("fleet.node.")]
    assert any(n.endswith("tokens_decoded") for n in node_gauges)
