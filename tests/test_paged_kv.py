"""Paged KV cache: block-table kernels, page-pool engine, allocator.

Four layers of invariants:

* kernel -- the block-table paged kernels (dense + q8) match the
  gathered-page jnp oracle at ragged lengths and SHUFFLED page tables
  (physical page naming must be invisible to the math);
* model -- `lm_decode_step` over a paged cache is bitwise-equal to the
  dense fixed-lane cache, including sliding-window rotation past the
  window (the rotation lives in the block table now);
* engine -- the paged ServeEngine is token-exact vs the dense engine
  for greedy AND seeded temperature, dense AND int8 caches, and admits
  strictly more concurrent requests than ``n_lanes`` at short contexts;
* allocator -- admit/retire churn never leaks or double-frees pages,
  and over-commit rejects admission while a lane is still free.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import (
    decode_attention_lengthaware_pallas, decode_attention_paged_pallas,
    decode_attention_paged_q8_pallas, decode_attention_paged_q8_ref,
    decode_attention_paged_ref, decode_attention_ref, gather_pages,
    kv_pages_fetched, quantize_kv_q8)
from repro.models import build_model
from repro.models.transformer import (init_cache, init_paged_cache,
                                      lm_decode_step)
from repro.serving import PagePool, Request, ServeEngine

pytestmark = pytest.mark.paged


# ----------------------------------------------------------------------
# kernel: block-table gather vs oracle
# ----------------------------------------------------------------------

def _shuffled_tables(b, t, n_pages, seed=0):
    """Disjoint, permuted page sets -- lanes never share physical pages
    and logical order is decoupled from physical order."""
    assert b * t <= n_pages
    perm = np.random.default_rng(seed).permutation(n_pages)[:b * t]
    return jnp.asarray(perm.reshape(b, t).astype(np.int32))


@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (4, 1)])
def test_paged_kernel_matches_ref_ragged(h, hkv):
    b, d, ps, t, n_pages = 5, 32, 32, 8, 48
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    kp = jax.random.normal(jax.random.PRNGKey(1), (n_pages, hkv, ps, d))
    vp = jax.random.normal(jax.random.PRNGKey(2), (n_pages, hkv, ps, d))
    bt = _shuffled_tables(b, t, n_pages)
    # ragged: dead lane, sub-page, page-aligned, partial, full
    lens = jnp.array([0, 7, 64, 130, 256], jnp.int32)
    out = decode_attention_paged_pallas(q, kp, vp, bt, lens,
                                        interpret=True)
    ref = decode_attention_paged_ref(q, kp, vp, bt, lens)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5
    assert jnp.all(out[0] == 0.0)          # dead lane: no live keys
    # and against the pinned dense parity reference on the gathered view
    gk, gv = gather_pages(kp, bt), gather_pages(vp, bt)
    dense = decode_attention_lengthaware_pallas(q, gk, gv, lens, bk=ps,
                                                interpret=True)
    assert jnp.max(jnp.abs(out - dense)) < 2e-5


def test_paged_q8_kernel_matches_ref():
    b, h, hkv, d, ps, t, n_pages, qblock = 3, 4, 2, 32, 32, 4, 16, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (n_pages, hkv, ps, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (n_pages, hkv, ps, d))
    kq, ks = quantize_kv_q8(k, qblock=qblock)
    vq, vs = quantize_kv_q8(v, qblock=qblock)
    bt = _shuffled_tables(b, t, n_pages, seed=3)
    lens = jnp.array([0, 50, 128], jnp.int32)
    out = decode_attention_paged_q8_pallas(q, kq, ks, vq, vs, bt, lens,
                                           qblock=qblock, interpret=True)
    ref = decode_attention_paged_q8_ref(q, kq, ks, vq, vs, bt, lens,
                                        qblock=qblock)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_kv_pages_fetched_contract():
    # the modeled fetch count BENCH_decode costs the paged section with
    pages = kv_pages_fetched(np.array([0, 1, 16, 17, 64, 200]), 4, 16)
    assert list(pages) == [1, 1, 1, 2, 4, 4]   # clamped at table width


# ----------------------------------------------------------------------
# model: paged cache == dense cache, bitwise
# ----------------------------------------------------------------------

@pytest.mark.parametrize("arch,kv_quant", [("qwen2.5-1.5b", None),
                                           ("qwen2.5-1.5b", "int8")])
def test_decode_step_paged_matches_dense(arch, kv_quant):
    cfg = get_config(arch, smoke=True)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, max_len, ps = 3, 32, 8
    dense = init_cache(cfg, B, max_len)
    paged = init_paged_cache(cfg, B, max_len, page_size=ps)
    t_w = paged["block_tables"].shape[1]
    paged["block_tables"] = _shuffled_tables(B, t_w, B * t_w, seed=1)
    toks = np.random.default_rng(2).integers(0, cfg.vocab_size, (B, 10),
                                             dtype=np.int32)
    for i in range(toks.shape[1]):
        ld, dense = lm_decode_step(params, cfg, dense,
                                   jnp.asarray(toks[:, i]))
        lp, paged = lm_decode_step(params, cfg, paged,
                                   jnp.asarray(toks[:, i]))
        assert jnp.array_equal(ld, lp), f"divergence at step {i}"


def test_window_rotation_in_block_table():
    """Sliding window as a FIXED page set rotated via the block table:
    decoding past the window stays bitwise-equal to the dense ring
    buffer (whose slot arithmetic is now the same ``pos % capacity``
    formula -- the rotation special case is gone)."""
    cfg = get_config("hymba-1.5b", smoke=True)     # window = 32
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, n = 2, cfg.sliding_window + 10              # exceed the window
    max_len = n + 6
    dense = init_cache(cfg, B, max_len)
    paged = init_paged_cache(cfg, B, max_len, page_size=8)
    t_w = paged["block_tables"].shape[1]
    assert t_w == cfg.sliding_window // 8          # fixed page set
    paged["block_tables"] = _shuffled_tables(B, t_w, B * t_w, seed=1)
    step_d = jax.jit(lambda c, t: lm_decode_step(params, cfg, c, t))
    step_p = jax.jit(lambda c, t: lm_decode_step(params, cfg, c, t))
    toks = np.random.default_rng(3).integers(0, cfg.vocab_size, (B, n),
                                             dtype=np.int32)
    for i in range(n):
        ld, dense = step_d(dense, jnp.asarray(toks[:, i]))
        lp, paged = step_p(paged, jnp.asarray(toks[:, i]))
    assert jnp.array_equal(ld, lp)


# ----------------------------------------------------------------------
# engine: token-exact parity + byte-proportional admission
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2.5-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
            for n in lens]


def _serve(cfg, params, prompts, max_new, **kw):
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng = ServeEngine(cfg, params, **kw)
    eng.run(reqs)
    return [tuple(r.generated) for r in reqs], eng


@pytest.mark.parametrize("temperature", [0.0, 0.9])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_engine_paged_token_exact(small_model, temperature, kv_quant):
    cfg, params = small_model
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    prompts = _prompts(cfg, [5, 9, 6, 12, 7], seed=1)
    kw = dict(n_lanes=2, max_len=32, dispatch_n=4,
              temperature=temperature, rng_seed=7)
    dense, _ = _serve(cfg, params, prompts, 6, **kw)
    paged, eng = _serve(cfg, params, prompts, 6, paged=True, page_size=8,
                        **kw)
    assert dense == paged
    eng.pool.check()
    assert eng.pool.n_in_use == 0          # everything freed at the end


def test_admission_scales_with_bytes_not_lanes(small_model):
    """Pool sized to 2 dense lanes' KV memory; mean live context at a
    quarter of max_len -> strictly more than 2 concurrent requests."""
    cfg, params = small_model
    max_len, ps = 32, 8
    dense_lanes = 2
    pool = dense_lanes * (max_len // ps)           # 8 pages
    eng = ServeEngine(cfg, params, n_lanes=8, max_len=max_len,
                      dispatch_n=4, paged=True, page_size=ps,
                      n_pages=pool)
    admitted = 0
    for i, p in enumerate(_prompts(cfg, [4] * 12, seed=2)):
        if not eng.admit(Request(uid=i, prompt=p, max_new_tokens=3)):
            break
        admitted += 1                              # 4+3+1 = 1 page each
    assert admitted > dense_lanes
    assert admitted == min(8, pool)                # byte-bound, not lanes


def test_overcommit_rejected_then_recovers(small_model):
    """A free lane with an exhausted pool must NOT admit; pages freed at
    retirement make the same request admissible again."""
    cfg, params = small_model
    # pool = one full context: the second long request cannot fit
    eng = ServeEngine(cfg, params, n_lanes=2, max_len=32, dispatch_n=4,
                      paged=True, page_size=8, n_pages=4)
    p1, p2 = _prompts(cfg, [10, 10], seed=3)
    r1 = Request(uid=0, prompt=p1, max_new_tokens=12)   # 23 slots: 3+ pages
    r2 = Request(uid=1, prompt=p2, max_new_tokens=12)
    assert eng.admit(r1)
    assert eng.free_lanes()                    # a lane IS free...
    assert not eng.can_admit(r2)
    assert not eng.admit(r2)                   # ...but the bytes are not
    assert eng.stats["kv_admit_blocked"] == 1
    while not r1.done:
        eng.decode_n()
    assert eng.admit(r2)                       # retirement freed the pages
    eng.pool.check()


def test_allocator_churn_leak_free(small_model):
    """Admit/retire churn over many more requests than lanes: page
    conservation holds throughout, the pool drains to empty, and the
    high-water mark never exceeds the pool."""
    cfg, params = small_model
    pool = 6
    eng = ServeEngine(cfg, params, n_lanes=3, max_len=32, dispatch_n=4,
                      paged=True, page_size=8, n_pages=pool)
    rng = np.random.default_rng(4)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 3 + (i % 7),
                                        dtype=np.int32),
                    max_new_tokens=1 + (i % 5))
            for i in range(17)]
    pending = list(reqs)
    while pending or any(r is not None for r in eng.lane_req):
        while pending and eng.free_lanes():
            if not eng.admit(pending[0]):
                break
            pending.pop(0)
        eng.decode_n()
        eng.pool.check()                       # conservation every block
        assert eng.pool.hwm <= pool
    assert all(r.done for r in reqs)
    assert [len(r.generated) for r in reqs] == [1 + (i % 5)
                                               for i in range(17)]
    assert eng.pool.n_in_use == 0 and eng.pool.n_free == pool
    assert eng.pool.alloc_count == eng.pool.free_count > 0
    assert eng.stats["kv_pages_hwm"] <= pool


def test_pagepool_double_free_and_reservation_guards():
    pool = PagePool(4, 8)
    assert pool.reserve(3)
    pages = pool.alloc(2)
    assert not pool.reserve(2)                 # 2 free - 1 reserved < 2
    pool.free(pages)
    with pytest.raises(AssertionError):
        pool.free(pages)                       # double free
    with pytest.raises(AssertionError):
        pool.alloc(2)                          # exceeds reservation
    pool.unreserve(1)
    pool.check()


def test_engine_paged_hybrid_window(small_model):
    """Hybrid (attention + SSM) engine with a sliding window: paged run
    token-exact vs dense, exercising block-table rotation plus the
    scan-based SSM prompt prefill in one path."""
    cfg = get_config("hymba-1.5b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg, [6, 7, 5], seed=5)
    dense, _ = _serve(cfg, params, prompts, 4, n_lanes=2, max_len=32,
                      dispatch_n=4)
    paged, eng = _serve(cfg, params, prompts, 4, n_lanes=2, max_len=32,
                        dispatch_n=4, paged=True, page_size=8)
    assert dense == paged
    eng.pool.check()


def test_window_prompt_longer_than_window_scatter(small_model):
    """A prompt that WRAPS the sliding window must land at its ring
    slots (`slot = pos % window`) in the prefill scatter, so the decode
    step's ring write evicts the true oldest position -- regression
    test for the un-rotated scatter (dense and paged engines vs a pure
    ring decode-stream oracle)."""
    cfg, params = small_model
    cfg = dataclasses.replace(cfg, sliding_window=16)
    plen, max_new, max_len = 20, 4, 32          # prompt wraps the window
    prompt = _prompts(cfg, [plen], seed=8)[0]
    # oracle: stream everything through the ring decode step
    cache = init_cache(cfg, 1, max_len)
    step = jax.jit(lambda c, t: lm_decode_step(params, cfg, c, t))
    logits = None
    for t in prompt:
        logits, cache = step(cache, jnp.asarray([t], jnp.int32))
    tok, want = int(jnp.argmax(logits[0])), []
    for _ in range(max_new):
        logits, cache = step(cache, jnp.asarray([tok], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
        want.append(tok)
    for paged in (False, True):
        got, _ = _serve(cfg, params, [prompt], max_new, n_lanes=1,
                        max_len=max_len, dispatch_n=4, paged=paged,
                        page_size=8)
        assert list(got[0]) == want, f"paged={paged}"


def test_dead_lane_writes_cannot_corrupt_live_pages(small_model):
    """A lane that is idle (never admitted, or retired and not yet
    reused) still steps inside the jitted batch and writes its frozen
    slot THROUGH ITS BLOCK TABLE.  Those writes must land on the scratch
    page, never on a page the allocator re-issued to a live lane --
    regression test for the stale-table aliasing bug (3 lanes, 2
    requests: lane 2's zero-initialized table would alias page 0, which
    belongs to request 0)."""
    cfg, params = small_model
    prompts = _prompts(cfg, [9, 7], seed=7)
    kw = dict(n_lanes=3, max_len=32, dispatch_n=4)
    dense, _ = _serve(cfg, params, prompts, 10, **kw)
    paged, eng = _serve(cfg, params, prompts, 10, paged=True, page_size=8,
                        **kw)
    assert dense == paged
    eng.pool.check()


def test_execution_replay_reports_page_stats(small_model):
    """The trace replay surfaces page-pool pressure next to the token
    accounting: hwm > 0 for a paged replay, token counts identical to
    the fixed-lane replay (layout invariance)."""
    from repro.fleet.execution import run_trace_on_engine
    from repro.fleet.workload import FleetRequest

    cfg, params = small_model
    trace = [FleetRequest(uid=i, arrival_s=0.1 * i, prompt_len=5 + i,
                          gen_len=4) for i in range(5)]
    dense = run_trace_on_engine(trace, cfg, params, n_lanes=2, max_len=32,
                                dispatch_n=4)
    paged = run_trace_on_engine(trace, cfg, params, n_lanes=2, max_len=32,
                                dispatch_n=4, paged=True, page_size=8)
    assert paged.gen_by_uid == dense.gen_by_uid
    assert paged.kv_pages_hwm > 0
    assert dense.kv_pages_hwm == 0 and dense.kv_admit_blocked == 0


def test_ssm_prefill_scan_matches_eager(small_model):
    """The bucketed lax.scan prompt prefill (state-masked pads) must
    reproduce the eager one-dispatch-per-token stream: compare against
    a hand-rolled eager replay of the first request."""
    cfg = get_config("mamba2-780m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg, [5, 6, 11], seed=6)
    served, eng = _serve(cfg, params, prompts, 5, n_lanes=3, max_len=32,
                         dispatch_n=4)
    # distinct buckets: 8 (len 5, 6) and 16 (len 11) -> two compiles
    assert eng.stats["ssm_prefill_compiles"] == 2
    # eager oracle for request 0: stream the prompt through decode_step
    cache = model.init_cache(params, 1, 32)
    step = jax.jit(lambda c, t: model.decode_step(params, c, t))
    logits = None
    for t in prompts[0]:
        logits, cache = step(cache, jnp.asarray([t], jnp.int32))
    tok = int(jnp.argmax(logits[0]))           # fed to decode, not emitted
    toks = []
    for _ in range(5):
        logits, cache = step(cache, jnp.asarray([tok], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
        toks.append(tok)
    assert list(served[0]) == toks
