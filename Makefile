# Test tiers.
#
# `make test` is the tier-1 verify command from ROADMAP.md (the bar every
# PR must hold).  `make test-fast` is the quick inner loop: it skips the
# @pytest.mark.slow subprocess/end-to-end tests (~7 min of the full run)
# so a fleet-sim or model change gets feedback in seconds, not minutes.

PYTEST := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest

.PHONY: test test-fast bench

test:
	$(PYTEST) -x -q

test-fast:
	$(PYTEST) -q -m "not slow"

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run
