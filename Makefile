# Test tiers.
#
# `make test` is the tier-1 verify command from ROADMAP.md (the bar every
# PR must hold).  `make test-fast` is the quick inner loop: it skips the
# @pytest.mark.slow subprocess/end-to-end tests (~7 min of the full run)
# so a fleet-sim or model change gets feedback in seconds, not minutes.
# `make test-paged` runs only the paged KV-cache layer (kernel/engine/
# allocator invariants) -- the quick loop when touching the paged path.
# `make test-preempt` runs the preemption/migration layer (checkpoint
# exactness, allocator churn under eviction, fleet migration).
# `make test-multimodel` runs the multi-model serving layer (ModelPool
# weight paging, MultiModelServeEngine exactness, fleet residency
# routing, PagePool shrink/grow invariants).
# `make test-obs` runs the telemetry layer (metrics registry, span
# tracer exactness-neutrality, event log, sim-to-real calibration gate).
# `make test-faults` runs the fault-tolerance layer (fault injection,
# checkpointed crash recovery, retry/hedging, degradation ladder,
# recovery-exactness oracle + hypothesis churn).
# `make test-prefix` runs the copy-on-write KV prefix-sharing layer
# (PagePool refcounts, radix prompt cache, CoW splits, shared-prefix
# exactness incl. evict/restore of prefix-hit lanes, cache flush on
# weight unload).
# `make test-analysis` runs the static-analysis layer (lint rules on
# synthetic snippets + the repo's own src/, sanitizer seeded-mutation
# detection, interleaving-checker exhaustive sweep, always-on
# invariants incl. the `python -O` subprocess pin).
# `make lint` runs the project lint (R001-R005) over src/ and fails on
# any unsuppressed finding -- the same gate test_analysis pins.
# `make check` is the umbrella: lint + the fast test tier.
# `make bench-smoke` runs the measured decode-path bench on a tiny config
# and emits BENCH_decode.json (tokens/s, dispatches/token, bytes/token,
# and the paged section: admission capacity, paged-vs-dense token parity,
# bytes/token parity) -- the decode perf trajectory is tracked from PR 2
# onward; the bench FAILS if the paged section is missing, paged
# bytes/token drifts >10% from dense at full occupancy, the telemetry
# section's sim-to-real calibration fit exceeds its declared tolerance,
# the faults section's recovery oracle / goodput-under-faults gate
# fails (crash recovery must be bit-exact and keep >= 90% goodput), or
# the prefix section fails its gates (shared-prefix streams must stay
# bit-exact, a cache hit must beat the miss TTFT, pages-saved > 0, and
# effective admission must reach >= 2x the no-sharing baseline at the
# bench's 50% overlap point), or the sanitize section fails (a fully
# sanitized shared-prefix run must report zero lifecycle violations,
# identical streams, and < 5% steady-state decode overhead), or the
# slo_tracing section fails (full observability stack -- tracing +
# flight recorder + SLO burn-rate controller -- must keep bit-identical
# streams at < 5% decode overhead; a crash replay must yield gap-free
# cross-engine RequestTimelines, one flight dump, and a ladder
# escalation; the FleetSim fault scenario must escalate AND de-escalate
# back to normal).  Each run also appends a row (tokens/s, percentiles,
# git sha, section verdicts) to BENCH_history.jsonl and FAILS on a >10%
# tokens/s regression vs the previous row.

PYTEST := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest
PYRUN  := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python

.PHONY: test test-fast test-paged test-preempt test-multimodel test-obs test-faults test-prefix test-analysis lint check bench bench-smoke

test:
	$(PYTEST) -x -q

test-fast:
	$(PYTEST) -q -m "not slow"

test-paged:
	$(PYTEST) -q -m paged

test-preempt:
	$(PYTEST) -q -m preempt

test-multimodel:
	$(PYTEST) -q -m multimodel

test-obs:
	$(PYTEST) -q -m obs

test-faults:
	$(PYTEST) -q -m faults

test-prefix:
	$(PYTEST) -q -m prefix

test-analysis:
	$(PYTEST) -q -m analysis

lint:
	$(PYRUN) -m repro.analysis.lint src/

check: lint test-fast

bench:
	$(PYRUN) -m benchmarks.run

bench-smoke:
	$(PYRUN) -m benchmarks.llm_decode --out BENCH_decode.json
