# Canonical serving environment -- source this before any serve/bench
# launch:
#
#     source scripts/serve_env.sh
#     python -m repro.launch.serve --arch qwen2.5-1.5b --smoke \
#         --paged --prefix-sharing
#
# Rationale (idioms from production JAX serving stacks, see SNIPPETS.md):
#
# * tcmalloc -- glibc malloc stalls multi-GiB host allocations (weight
#   staging, checkpoint gathers); tcmalloc keeps them off the serving
#   hot path.  The preload is skipped when the library is absent, so
#   the script is safe to source on minimal containers.
# * XLA_FLAGS -- one host-platform device (the engine shards lanes, not
#   processes).  On TPU builds additionally set
#   "--xla_step_marker_location=1" (step markers at the outer while
#   loop, so profile traces cut at dispatch boundaries, matching the
#   span tracer); CPU-only XLA builds reject the flag, so it stays off
#   by default.
# * TF_CPP_MIN_LOG_LEVEL=4 -- silence the TF/XLA banner spam that
#   otherwise drowns the serve launcher's throughput lines.
# * JAX_COMPILATION_CACHE_DIR -- persistent XLA compilation cache: a
#   relaunch (same config/buckets) reuses compiled prefill/decode
#   executables instead of re-tracing.  The serve launcher and
#   bench-smoke report their steady-state compile counters so a cold
#   cache is visible (see BENCH_decode.json "warm_start").

_TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [ -e "${_TCMALLOC}" ]; then
    export LD_PRELOAD="${_TCMALLOC}"                  # faster malloc
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi
unset _TCMALLOC

export TF_CPP_MIN_LOG_LEVEL=4
export XLA_FLAGS="--xla_force_host_platform_device_count=1"
# export XLA_FLAGS="--xla_step_marker_location=1 ${XLA_FLAGS}"  # TPU builds

# Persistent compilation cache (override the location before sourcing
# to share one cache across checkouts).
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-${HOME}/.cache/repro-jax}"
mkdir -p "${JAX_COMPILATION_CACHE_DIR}"
